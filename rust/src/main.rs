//! `raas` — CLI for the RaaS serving stack and the paper-figure harness.
//!
//! Commands:
//!   inspect                    show artifact metadata
//!   run                        decode one sampled problem end-to-end
//!   sweep                      real-model accuracy sweep (policies × budgets)
//!   serve                      multi-replica router + continuous batching demo
//!   fig1 fig2 fig3 fig6 fig7 fig8 fig9
//!                              regenerate each paper figure (see DESIGN.md)
//!
//! Common flags: --artifacts DIR --policy P --budget N --alpha A --seed S

use anyhow::{bail, Result};

use raas::config::{BackendKind, EngineConfig, PolicyKind, PreemptMode};
use raas::coordinator::batcher::BatcherConfig;
use raas::coordinator::request::{Outcome, Request, Response};
use raas::coordinator::router::RoutePolicy;
use raas::coordinator::supervisor::{Supervisor, SupervisorConfig};
use raas::engine::{Engine, GenOptions};
use raas::runtime::FaultSchedule;
use raas::util::clock::WallClock;
use raas::figures;
use raas::util::cli::Args;
use raas::util::rng::Rng;
use raas::util::stats::Summary;
use raas::workload::{parse_answer, Problem};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("inspect") => inspect(args),
        Some("run") => run_one(args),
        Some("sweep") => sweep(args),
        Some("serve") => serve(args),
        Some("fig1") => figures::fig1::run(args),
        Some("fig2") => figures::fig2::run(args),
        Some("fig3") => figures::fig3::run(args),
        Some("fig6") => figures::fig6::run(args),
        Some("fig7") => figures::fig7::run(args),
        Some("fig8") => figures::fig8::run(args),
        Some("fig9") => figures::fig9::run(args),
        Some("ablate") => figures::ablate::run(args),
        Some("perf") => perf(args),
        Some(other) => bail!("unknown command '{other}' (run `raas` for help)"),
        None => {
            print_help();
            Ok(())
        }
    }?;
    args.finish()?;
    Ok(())
}

fn print_help() {
    println!(
        "raas — Reasoning-Aware Attention Sparsity serving stack\n\
         \n\
         usage: raas <command> [--flags]\n\
         \n\
         commands:\n\
           inspect     show model metadata (backend, capacities, corpus)\n\
           run         decode one sampled problem (--policy, --budget, --steps)\n\
           sweep       model accuracy sweep (--policies, --budgets, --problems)\n\
           serve       supervised multi-replica serving demo (--replicas,\n\
                       --requests, --rate, --route rr|least|affinity|scored,\n\
                       --prefill-budget N for chunked admission,\n\
                       --prefill-concurrency K to co-admit K prompts,\n\
                       --preempt-mode recompute|restore, --deadline-ms N,\n\
                       --retry N failovers, --max-queue N sheds beyond depth,\n\
                       --hang-timeout-ms N watchdog, and fault demos\n\
                       --crash-tick N / --hang-tick N on replica 0)\n\
           fig1..fig9  regenerate the paper's figures (writes results/*.csv)\n\
         \n\
         common flags: --backend sim|xla  --artifacts DIR\n\
           --policy dense|sink|h2o|quest|raas|rpc|lessismore\n\
           --budget N  --alpha A  --seed S  --out results/\n\
           --kv-dtype f32|fp8|int8 (KV-slab storage; f32 is bit-exact)\n\
         \n\
         the default `sim` backend is a deterministic pure-Rust surrogate\n\
         (no artifacts needed); `xla` drives the PJRT/HLO path and needs a\n\
         build with --features backend-xla plus `make artifacts`.  Passing\n\
         --artifacts without --backend implies `--backend xla`."
    );
}

fn inspect(args: &Args) -> Result<()> {
    let cfg = EngineConfig::from_args(args)?;
    let meta = cfg.resolve_meta()?;
    println!("backend: {}", cfg.backend);
    println!("artifacts: {:?}", meta.dir);
    println!("model: {:?}", meta.model);
    println!("trained weights: {}", meta.trained);
    println!("page size: {}", meta.page_size);
    println!("slot capacities: {:?}", meta.capacities);
    println!("prefill sizes: {:?}", meta.prefill_sizes);
    println!(
        "corpus: steps {}..{}, lookback {}",
        meta.corpus.min_steps, meta.corpus.max_steps, meta.corpus.max_lookback
    );
    println!("kv bytes/token (all layers): {}", meta.model.kv_bytes_per_token());
    Ok(())
}

fn run_one(args: &Args) -> Result<()> {
    let cfg = EngineConfig::from_args(args)?;
    let steps = args.usize_opt("steps");
    let mut engine = Engine::new(cfg)?;
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let p = Problem::sample(&mut rng, &spec, steps);
    let prompt = p.encode_prompt(&spec);
    println!("prompt:   {}", engine.tokenizer.decode(&prompt));
    let out = engine.generate(
        &prompt,
        &GenOptions { max_new: args.usize_or("max-new", 160), ..Default::default() },
    )?;
    println!("decoded:  {}", engine.tokenizer.decode(&out.tokens));
    println!("expected: {}", engine.tokenizer.decode(&p.encode_decode(&spec)));
    let got = engine.tokenizer.parse_answer(&out.tokens);
    println!(
        "\nbackend={} policy={} budget={} → answer {:?} (expected {}), {} tokens, \
         prefill {:.0} ms, decode {:.0} ms ({:.1} ms/token), peak KV {} bytes",
        engine.cfg.backend,
        engine.policy_kind(),
        engine.cfg.budget,
        got,
        p.answer(),
        out.tokens.len(),
        1e3 * out.prefill_secs,
        1e3 * out.decode_secs,
        1e3 * out.decode_secs / out.tokens.len().max(1) as f64,
        out.peak_resident_bytes,
    );
    Ok(())
}

/// End-to-end validation of the Figure-6 orderings: accuracy per policy ×
/// budget on n sampled problems.  Absolute accuracies are only meaningful
/// on the trained model (`--backend xla`); the sim surrogate cannot solve
/// the task and the output says so.
fn sweep(args: &Args) -> Result<()> {
    let n = args.usize_or("problems", 30);
    let budgets = args.usize_list_or("budgets", &[64, 128, 256]);
    let policies = args.str_list_or(
        "policies",
        &["dense", "sink", "h2o", "quest", "raas", "rpc", "lessismore"],
    );
    let out_dir = figures::common::results_dir(args.str_opt("out"))?;
    // parse once: per-cell configs are clones with policy/budget overridden
    let base_cfg = EngineConfig::from_args(args)?;
    let backend = base_cfg.backend;
    if backend == BackendKind::Sim {
        println!(
            "note: sweeping the `sim` surrogate backend — accuracies are not \
             paper-comparable (pass --backend xla for the trained model)"
        );
    }

    let mut rows = Vec::new();
    let mut tbl = Vec::new();
    for pname in &policies {
        let kind = PolicyKind::parse(pname)?;
        let mut line = vec![pname.clone()];
        for &budget in &budgets {
            let mut cfg = base_cfg.clone();
            cfg.policy = kind;
            cfg.budget = budget;
            let mut engine = Engine::new_with_capacities(cfg, &[64, 128, 256, 512, 2048])?;
            let spec = engine.meta.corpus.clone();
            let mut rng = Rng::new(args.u64_or("seed", 42));
            let mut correct = 0usize;
            let mut decode_len = Summary::new();
            for _ in 0..n {
                let p = Problem::sample(&mut rng, &spec, None);
                let prompt = p.encode_prompt(&spec);
                let opts = GenOptions {
                    max_new: spec.max_decode_tokens(spec.max_steps),
                    ..Default::default()
                };
                let out = engine.generate(&prompt, &opts)?;
                decode_len.add(out.tokens.len() as f64);
                if engine.tokenizer.parse_answer(&out.tokens) == Some(p.answer()) {
                    correct += 1;
                }
            }
            let acc = correct as f64 / n as f64;
            rows.push(vec![
                pname.clone(),
                budget.to_string(),
                format!("{acc:.3}"),
                format!("{:.1}", decode_len.mean()),
            ]);
            line.push(format!("{acc:.2}"));
            println!("{pname} @ {budget}: acc {acc:.3} (decode mean {:.0})", decode_len.mean());
        }
        tbl.push(line);
    }
    let path = out_dir.join(format!("sweep_{}.csv", backend.name()));
    figures::common::write_csv(&path, &["policy", "budget", "accuracy", "mean_decode_len"], &rows)?;
    println!("\naccuracy sweep on the `{backend}` backend ({n} problems/cell):");
    let mut headers = vec!["policy"];
    let bs: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
    headers.extend(bs.iter().map(|s| s.as_str()));
    figures::common::print_table(&headers, &tbl);
    println!("wrote {path:?}");
    Ok(())
}

/// Supervised multi-replica serving demo: health/KV-aware routing +
/// continuous batching under a Poisson or batch arrival workload, with
/// crash/hang recovery; reports throughput and latency percentiles.
fn serve(args: &Args) -> Result<()> {
    let replicas = args.usize_or("replicas", 2);
    let n_requests = args.usize_or("requests", 16);
    let rate = args.f64_or("rate", 0.0); // 0 = offline batch
    let route = RoutePolicy::parse(&args.str_or("route", "scored"))?;
    let max_batch = args.usize_or("max-batch", 4);
    // Sarathi-style chunked admission: at most this many prompt tokens per
    // scheduler tick (absent = legacy prefill-first whole-prompt admission).
    let prefill_budget = args.usize_opt("prefill-budget");
    // Concurrent chunked admission: how many prompts may prefill at once,
    // their chunks packed into one batched call (1 = PR-4 one-at-a-time).
    let prefill_concurrency = args.usize_or("prefill-concurrency", 1);
    // Robustness knobs (DESIGN.md §6): what happens to a preempted
    // sequence's pages, per-request deadline + router retry budget, and
    // queue-depth load shedding.
    let preempt_mode = PreemptMode::parse(&args.str_or("preempt-mode", "recompute"))?;
    let deadline_ms = args.u64_or("deadline-ms", 0); // 0 = no deadline
    let retries = args.usize_or("retry", 1) as u32;
    let max_queue_depth = args.usize_opt("max-queue");
    // Supervision knobs: watchdog hang timeout, plus optional demo faults
    // injected into replica 0's tick loop.
    let hang_timeout_ms = args.u64_or("hang-timeout-ms", 1000);
    let crash_tick = args.usize_opt("crash-tick");
    let hang_tick = args.usize_opt("hang-tick");
    let cfg = EngineConfig::from_args(args)?;
    let caps: Option<Vec<usize>> = Some(args.usize_list_or("capacities", &[64, 128, 256, 512]));

    println!("spawning {replicas} replica(s) (policy={}, budget={})…", cfg.policy, cfg.budget);
    let bcfg = BatcherConfig { max_batch,
                               prefill_token_budget: prefill_budget,
                               prefill_concurrency,
                               preempt_mode,
                               max_queue_depth };
    let meta = cfg.resolve_meta()?;
    let spec = meta.corpus.clone();
    let mut fault0 = None;
    if let Some(t) = crash_tick {
        fault0 = Some(FaultSchedule::new(cfg.seed).crash_at_tick(t as u64));
    } else if let Some(t) = hang_tick {
        fault0 = Some(FaultSchedule::new(cfg.seed).hang_at_tick(t as u64));
    }
    let scfg = SupervisorConfig { hang_timeout_ms, redispatch_retries: retries.max(1) };
    let mut sup = Supervisor::spawn(
        replicas,
        cfg,
        bcfg,
        caps,
        route,
        scfg,
        WallClock::shared(),
        vec![fault0],
    )?;

    let mut rng = Rng::new(args.u64_or("seed", 123));
    let (tx, rx) = std::sync::mpsc::channel::<Response>();
    let t0 = std::time::Instant::now();
    let mut answers = Vec::new();
    for id in 0..n_requests as u64 {
        if rate > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rate)));
        }
        let p = Problem::sample(&mut rng, &spec, None);
        answers.push(p.answer());
        let mut req = Request::new(
            id,
            p.encode_prompt(&spec),
            spec.max_decode_tokens(spec.max_steps),
            tx.clone(),
        )
        .with_retries(retries);
        if deadline_ms > 0 {
            req = req.with_deadline_ms(deadline_ms);
        }
        if let Err(se) = sup.submit(req) {
            // Every replica refused (or is dead): answer the caller with a
            // failure instead of silently dropping the request.
            let resp = Response::err(se.req.id, se.req.submitted, se.reason);
            let _ = se.req.reply.send(resp);
        }
        sup.poll(); // keep recovery responsive while arrivals trickle in
    }
    drop(tx);
    while !sup.poll() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }

    let mut jct = Summary::new();
    let mut ttft = Summary::new();
    let mut tokens = 0usize;
    let mut correct = 0usize;
    let mut errors = 0usize;
    let mut sheds = 0usize;
    for resp in rx.iter() {
        match resp.outcome {
            Outcome::Shed => {
                eprintln!("request {} shed: {}", resp.id,
                          resp.error.as_deref().unwrap_or("unknown"));
                sheds += 1;
                continue;
            }
            Outcome::Failed => {
                eprintln!("request {} failed: {}", resp.id,
                          resp.error.as_deref().unwrap_or("unknown"));
                errors += 1;
                continue;
            }
            Outcome::Done => {}
        }
        jct.add(resp.jct_secs);
        ttft.add(resp.ttft_secs);
        tokens += resp.tokens.len();
        if parse_answer(&spec, &resp.tokens) == Some(answers[resp.id as usize]) {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let done = jct.count();
    println!("\nserved {done}/{n_requests} requests on {replicas} replica(s) in {wall:.1}s");
    println!("throughput: {:.2} req/s, {:.1} tok/s", done as f64 / wall, tokens as f64 / wall);
    println!("JCT  p50 {:.2}s  p99 {:.2}s  mean {:.2}s", jct.percentile(50.0),
             jct.percentile(99.0), jct.mean());
    println!("TTFT p50 {:.0}ms p99 {:.0}ms", 1e3 * ttft.percentile(50.0),
             1e3 * ttft.percentile(99.0));
    println!("accuracy: {:.2} ({correct}/{done}), errors {errors}, shed {sheds}",
             correct as f64 / done.max(1) as f64);
    let r = sup.router();
    println!(
        "supervision: crashes {} hangs {} redispatched {} | routing: affinity hits {} \
         failovers {} breaker opens {} quarantines {}",
        sup.crashes, sup.hangs, sup.redispatched, r.affinity_hits, r.failovers,
        r.breaker_opens, r.quarantines
    );
    sup.shutdown();
    Ok(())
}

/// Decode hot-path phase breakdown: where each decode-step millisecond goes
/// (PJRT executions vs rust-side policy bookkeeping vs page gather).
fn perf(args: &Args) -> Result<()> {
    let force = args.usize_or("decode", 512);
    let policies = args.str_list_or("policies", &["dense", "quest", "raas"]);
    for pname in &policies {
        let mut cfg = EngineConfig::from_args(args)?;
        cfg.policy = PolicyKind::parse(pname)?;
        let mut engine = Engine::new_with_capacities(cfg, &[64, 128, 256, 512, 1024, 2048])?;
        let spec = engine.meta.corpus.clone();
        let mut rng = Rng::new(args.u64_or("seed", 0));
        let mut prompt = Vec::new();
        while prompt.len() < 128 {
            prompt.extend(Problem::sample(&mut rng, &spec, None).encode_prompt(&spec));
        }
        prompt.truncate(128);
        let out = engine.generate(
            &prompt,
            &GenOptions { max_new: force, force_len: Some(force), ..Default::default() },
        )?;
        let g = |n: &str| engine.metrics.timer(n).map(|t| t.mean() * 1e3).unwrap_or(0.0);
        let (e, p, ga) = (g("step.exec_secs"), g("step.policy_secs"), g("step.gather_secs"));
        let total = 1e3 * out.decode_secs / force as f64;
        println!(
            "{pname:>6}: {total:.3} ms/token | exec {e:.3} ms ({:.0}%) | policy {p:.4} ms \
             ({:.1}%) | gather {ga:.4} ms ({:.1}%) | other {:.3} ms",
            100.0 * e / total,
            100.0 * p / total,
            100.0 * ga / total,
            total - e - p - ga
        );
    }
    Ok(())
}
