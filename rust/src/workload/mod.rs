//! Workload generation: the rust mirror of `python/compile/corpus.py` (kept
//! in sync through `artifacts/meta.json`) plus the dataset length profiles
//! behind the paper's Figure 1 CDFs.

use crate::config::CorpusSpec;
use crate::util::rng::Rng;

/// One synthetic chain-arithmetic problem (mirror of corpus.Problem).
#[derive(Debug, Clone)]
pub struct Problem {
    /// Starting value v_0 (a single digit).
    pub a: u8,
    /// (r, op, b): step i computes v_i = v_r op b (mod 10); op is a token id.
    pub steps: Vec<(usize, u32, u8)>,
    /// Every intermediate value v_0..v_k (values[i] is step i's result).
    pub values: Vec<u8>,
}

/// One chain step: `x op y (mod 10)` where `op` is a corpus operator token.
pub fn apply_op(spec: &CorpusSpec, x: u8, op: u32, y: u8) -> u8 {
    let (x, y) = (x as i32, y as i32);
    let r = if op == spec.plus {
        x + y
    } else if op == spec.minus {
        x - y
    } else if op == spec.times {
        x * y
    } else {
        panic!("not an op token: {op}")
    };
    (r.rem_euclid(10)) as u8
}

impl Problem {
    /// Sample a `k`-step problem (`k = None`: uniform in the spec's step
    /// range) — mirror of `corpus.sample_problem`.
    pub fn sample(rng: &mut Rng, spec: &CorpusSpec, k: Option<usize>) -> Problem {
        let k = k.unwrap_or_else(|| rng.range(spec.min_steps, spec.max_steps + 1));
        let a = rng.range(0, 10) as u8;
        let mut values = vec![a];
        let mut steps = Vec::with_capacity(k);
        let ops = [spec.plus, spec.minus, spec.times];
        for i in 1..=k {
            let lo = i.saturating_sub(spec.max_lookback);
            let r = rng.range(lo, i);
            let op = *rng.choose(&ops);
            let b = rng.range(0, 10) as u8;
            steps.push((r, op, b));
            values.push(apply_op(spec, values[r], op, b));
        }
        Problem { a, steps, values }
    }

    /// The final chain value v_k — the digit the model must emit after ANS.
    pub fn answer(&self) -> u8 {
        *self.values.last().unwrap()
    }

    /// prompt = BOS Q a [IDX_i IDX_r op b]*k EQ  (mirror of
    /// corpus.encode_prompt — instruction groups are content-addressed by
    /// their dedicated single index tokens).
    pub fn encode_prompt(&self, spec: &CorpusSpec) -> Vec<u32> {
        let mut t = vec![spec.bos, spec.q, spec.dig0 + self.a as u32];
        for (i, &(r, op, b)) in self.steps.iter().enumerate() {
            let i = i + 1;
            t.push(spec.idx0 + i as u32);
            t.push(spec.idx0 + r as u32);
            t.push(op);
            t.push(spec.dig0 + b as u32);
        }
        t.push(spec.eq);
        t
    }

    /// decode = [STEP IDX_i IDX_r v_r op b IDX_i v_i SEP]*k ANS v_k DOT EOS
    /// (fully decomposed chain of thought — see corpus.py for the rationale)
    pub fn encode_decode(&self, spec: &CorpusSpec) -> Vec<u32> {
        let mut t = Vec::new();
        for i in 1..=self.steps.len() {
            let (r, op, b) = self.steps[i - 1];
            t.push(spec.step);
            t.push(spec.idx0 + i as u32);
            t.push(spec.idx0 + r as u32);
            t.push(spec.dig0 + self.values[r] as u32);
            t.push(op);
            t.push(spec.dig0 + b as u32);
            t.push(spec.idx0 + i as u32);
            t.push(spec.dig0 + self.values[i] as u32);
            t.push(spec.sep);
        }
        t.push(spec.ans);
        t.push(spec.dig0 + self.answer() as u32);
        t.push(spec.dot);
        t.push(spec.eos);
        t
    }

    /// Absolute position of emitted value v_i in the full stream (i >= 1).
    pub fn milestone_position(&self, prompt_len: usize, i: usize) -> usize {
        prompt_len + 9 * (i - 1) + 7
    }

    /// Absolute position of prompt operand b_i (step i, 1-based).
    pub fn phoenix_position(&self, i: usize) -> usize {
        3 + 4 * (i - 1) + 3
    }
}

/// Extract the answer digit from a decoded stream (mirror of parse_answer).
pub fn parse_answer(spec: &CorpusSpec, decoded: &[u32]) -> Option<u8> {
    for (i, &t) in decoded.iter().enumerate() {
        if t == spec.ans {
            if let Some(&d) = decoded.get(i + 1) {
                if d >= spec.dig0 && d < spec.dig0 + 10 {
                    return Some((d - spec.dig0) as u8);
                }
            }
        }
    }
    None
}

/// Render a token stream as readable text (debugging / trace output).
pub fn detok(spec: &CorpusSpec, tokens: &[u32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            if t == spec.pad { "·".into() }
            else if t == spec.bos { "<bos>".into() }
            else if t == spec.eos { "<eos>".into() }
            else if t == spec.q { "Q".into() }
            else if t == spec.eq { "=".into() }
            else if t == spec.sep { ";".into() }
            else if t == spec.step { "s".into() }
            else if t == spec.ans { "A".into() }
            else if t == spec.dot { ".".into() }
            else if t == spec.plus { "+".into() }
            else if t == spec.minus { "-".into() }
            else if t == spec.times { "*".into() }
            else if t >= spec.dig0 && t < spec.dig0 + 10 { (t - spec.dig0).to_string() }
            else if t >= spec.idx0 && t < spec.idx0 + spec.n_idx { format!("#{}", t - spec.idx0) }
            else { format!("<{t}>") }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

// ---------------------------------------------------------------------------
// Dataset length profiles (Figure 1)
// ---------------------------------------------------------------------------

/// Prefill/decode length distributions for one dataset family.
#[derive(Debug, Clone, Copy)]
pub struct LengthProfile {
    /// Dataset name as used by `--dataset` flags and figure labels.
    pub name: &'static str,
    /// log-normal (mu, sigma) of the prefill length in tokens
    pub prefill: (f64, f64),
    /// log-normal (mu, sigma) of the decode length in tokens
    pub decode: (f64, f64),
    /// Whether this is a reasoning (long-decode) family — Figure 1(b).
    pub reasoning: bool,
}

/// Long-prefill (RAG-style, LongBench) profiles — Figure 1(a).
pub const LONGBENCH: [LengthProfile; 5] = [
    LengthProfile {
        name: "narrativeqa",
        prefill: (9.8, 0.45),
        decode: (2.7, 0.5),
        reasoning: false,
    },
    LengthProfile { name: "qasper", prefill: (8.3, 0.5), decode: (2.9, 0.6), reasoning: false },
    LengthProfile { name: "hotpotqa", prefill: (9.1, 0.35), decode: (2.5, 0.5), reasoning: false },
    LengthProfile { name: "triviaqa", prefill: (8.9, 0.5), decode: (2.3, 0.55), reasoning: false },
    LengthProfile {
        name: "gov_report",
        prefill: (9.0, 0.4),
        decode: (6.2, 0.35),
        reasoning: false,
    },
];

/// Long-decode (math reasoning) profiles — Figure 1(b); calibrated to the
/// paper's Marco-O1 CDFs (prefill ≈ 40–200 tokens, decode ≈ 200–2000).
pub const MATH: [LengthProfile; 3] = [
    LengthProfile { name: "gsm8k", prefill: (4.1, 0.35), decode: (5.6, 0.45), reasoning: true },
    LengthProfile { name: "math500", prefill: (4.4, 0.40), decode: (6.1, 0.50), reasoning: true },
    LengthProfile { name: "aime", prefill: (4.7, 0.35), decode: (6.7, 0.45), reasoning: true },
];

impl LengthProfile {
    /// Look up a profile across both families by dataset name.
    pub fn by_name(name: &str) -> Option<LengthProfile> {
        LONGBENCH.iter().chain(MATH.iter()).find(|p| p.name == name).copied()
    }
    /// Draw one prefill length (tokens, floored at 4).
    pub fn sample_prefill(&self, rng: &mut Rng) -> usize {
        rng.lognormal(self.prefill.0, self.prefill.1).round().max(4.0) as usize
    }
    /// Draw one decode length (tokens, floored at 8).
    pub fn sample_decode(&self, rng: &mut Rng) -> usize {
        rng.lognormal(self.decode.0, self.decode.1).round().max(8.0) as usize
    }
}

/// Request arrival process for the coordinator benches.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// All requests available at t=0 (offline batch).
    Batch,
    /// Poisson with the given rate (requests/second).
    Poisson(f64),
}

impl Arrival {
    /// Arrival offsets in seconds for `n` requests.
    pub fn times(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        match self {
            Arrival::Batch => vec![0.0; n],
            Arrival::Poisson(rate) => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exp(*rate);
                        t
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
pub(crate) fn test_spec() -> CorpusSpec {
    CorpusSpec {
        min_steps: 2, max_steps: 16, max_lookback: 6,
        pad: 0, bos: 1, eos: 2, q: 3, eq: 4, sep: 5, step: 6, ans: 7,
        dot: 8, plus: 9, minus: 10, times: 11, dig0: 12, idx0: 22, n_idx: 20,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        test_spec()
    }

    #[test]
    fn problem_values_consistent() {
        let s = spec();
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let p = Problem::sample(&mut rng, &s, None);
            assert_eq!(p.values[0], p.a);
            for (i, &(r, op, b)) in p.steps.iter().enumerate() {
                let i = i + 1;
                assert!(r < i && i - r <= s.max_lookback);
                assert_eq!(p.values[i], apply_op(&s, p.values[r], op, b));
            }
        }
    }

    #[test]
    fn encode_lengths() {
        let s = spec();
        let mut rng = Rng::new(1);
        let p = Problem::sample(&mut rng, &s, Some(16));
        assert_eq!(p.encode_prompt(&s).len(), 3 + 4 * 16 + 1);
        assert_eq!(p.encode_decode(&s).len(), 9 * 16 + 4);
    }

    #[test]
    fn parse_answer_roundtrip() {
        let s = spec();
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let p = Problem::sample(&mut rng, &s, None);
            assert_eq!(parse_answer(&s, &p.encode_decode(&s)), Some(p.answer()));
        }
    }

    #[test]
    fn positions_point_at_tokens() {
        let s = spec();
        let mut rng = Rng::new(3);
        let p = Problem::sample(&mut rng, &s, Some(5));
        let prompt = p.encode_prompt(&s);
        let mut full = prompt.clone();
        full.extend(p.encode_decode(&s));
        for i in 1..=5 {
            assert_eq!(full[p.milestone_position(prompt.len(), i)], s.dig0 + p.values[i] as u32);
            let (_, _, b) = p.steps[i - 1];
            assert_eq!(full[p.phoenix_position(i)], s.dig0 + b as u32);
        }
    }

    #[test]
    fn length_profiles_sane() {
        let mut rng = Rng::new(4);
        let gsm = LengthProfile::by_name("gsm8k").unwrap();
        let nqa = LengthProfile::by_name("narrativeqa").unwrap();
        let mut gsm_pre = 0.0;
        let mut nqa_pre = 0.0;
        let mut gsm_dec = 0.0;
        for _ in 0..200 {
            gsm_pre += gsm.sample_prefill(&mut rng) as f64;
            nqa_pre += nqa.sample_prefill(&mut rng) as f64;
            gsm_dec += gsm.sample_decode(&mut rng) as f64;
        }
        // reasoning: short prefill, long decode; RAG: the opposite
        assert!(nqa_pre / 200.0 > 20.0 * (gsm_pre / 200.0));
        assert!(gsm_dec / 200.0 > 3.0 * (gsm_pre / 200.0));
    }

    #[test]
    fn poisson_arrivals_increase() {
        let mut rng = Rng::new(5);
        let times = Arrival::Poisson(10.0).times(&mut rng, 50);
        for w in times.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(Arrival::Batch.times(&mut rng, 3).iter().all(|&t| t == 0.0));
    }

    #[test]
    fn detok_readable() {
        let s = spec();
        let mut rng = Rng::new(6);
        let p = Problem::sample(&mut rng, &s, Some(2));
        let txt = detok(&s, &p.encode_prompt(&s));
        assert!(txt.contains('Q') && txt.contains('='));
    }
}
