//! # RaaS — Reasoning-Aware Attention Sparsity (full-system reproduction)
//!
//! A three-layer serving stack reproducing *"Efficient Long-Decoding
//! Inference with Reasoning-Aware Attention Sparsity"* (Hu et al., ACL 2025
//! Findings):
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request router,
//!   continuous batcher, paged KV-cache manager and the seven-policy
//!   sparsity zoo (Dense, StreamingLLM/Sink, H2O, Quest, **RaaS**, plus the
//!   post-paper RPC and LessIsMore follow-ons), and the trace-driven
//!   evaluation substrate that regenerates every figure of the paper's
//!   evaluation section.
//! * **Layer 2** — a small GQA transformer authored in JAX (`python/compile`),
//!   AOT-lowered to HLO-text executables with the weights baked in.
//! * **Layer 1** — Pallas paged sparse-attention kernel, lowered inside the
//!   same executables.
//!
//! The [`runtime`] module exposes pluggable execution backends behind the
//! [`runtime::Backend`] trait: the default [`runtime::SimBackend`] is a
//! deterministic pure-Rust transformer surrogate (hermetic — CI runs on
//! it), while `--features backend-xla` compiles the PJRT runtime that loads
//! the AOT artifacts through the `xla` crate (python never runs on the
//! request path).
//!
//! See `DESIGN.md` for the architecture, backend/feature matrix and the
//! per-experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.

// The public serving API is fully documented and the docs are
// CI-enforced: `cargo doc --no-deps` runs with `RUSTDOCFLAGS="-D
// warnings"`, so a public item without docs fails the build there.
#![warn(missing_docs)]
// Stylistic lints the codebase deliberately trades for explicit indexed hot
// loops and wide call signatures (kernel-shaped APIs).  `unknown_lints`
// keeps the list portable across clippy versions.
#![allow(unknown_lints)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]
#![allow(clippy::field_reassign_with_default)]

// Every public module — including the in-tree harness substrates (offline
// stand-ins for criterion/serde/clap/rand) and the figure commands — is
// item-level documented and held to the same `-D warnings` rustdoc gate as
// the serving API.
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
