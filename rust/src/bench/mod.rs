//! Criterion-like micro/endtoend bench harness (criterion is unavailable
//! offline).  Warmup, fixed-iteration timing, mean/σ/percentiles, aligned
//! table output and JSON dump for EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Iteration counts and time cap for one [`Bencher`] run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Untimed calls before sampling starts (cache/branch warmup).
    pub warmup_iters: usize,
    /// Timed samples per benchmark (one call = one sample).
    pub iters: usize,
    /// Hard cap on wall time per benchmark (stops early, keeps samples).
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 30, max_time: Duration::from_secs(20) }
    }
}

/// Timing statistics for one named benchmark (all times nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label as printed and dumped.
    pub name: String,
    /// Samples actually collected (may stop early at `max_time`).
    pub iters: usize,
    /// Sample mean.
    pub mean_ns: f64,
    /// Sample standard deviation.
    pub std_ns: f64,
    /// Median sample.
    pub p50_ns: f64,
    /// 99th-percentile sample.
    pub p99_ns: f64,
    /// Fastest sample.
    pub min_ns: f64,
}

impl BenchResult {
    /// This result as one JSON object row (the `BENCH_*.json` record shape).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::from(self.iters)),
            ("mean_ns", Json::from(self.mean_ns)),
            ("std_ns", Json::from(self.std_ns)),
            ("p50_ns", Json::from(self.p50_ns)),
            ("p99_ns", Json::from(self.p99_ns)),
            ("min_ns", Json::from(self.min_ns)),
        ])
    }
}

/// Bench runner: times closures under a [`BenchConfig`] and accumulates
/// [`BenchResult`]s for table printing and JSON dump.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    /// A runner with explicit iteration counts.
    pub fn new(cfg: BenchConfig) -> Self {
        Bencher { cfg, results: Vec::new() }
    }

    /// A runner with [`BenchConfig::default`] counts.
    pub fn with_defaults() -> Self {
        Self::new(BenchConfig::default())
    }

    /// Time `f` (one call = one sample).  Return value is black-boxed.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        for _ in 0..self.cfg.iters {
            let t0 = Instant::now();
            black_box(f());
            s.add(t0.elapsed().as_nanos() as f64);
            if start.elapsed() > self.cfg.max_time && s.count() >= 5 {
                break;
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: s.count(),
            mean_ns: s.mean(),
            std_ns: s.std(),
            p50_ns: s.percentile(50.0),
            p99_ns: s.percentile(99.0),
            min_ns: s.min(),
        };
        println!("{}", format_row(&r));
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// All results collected so far, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the aligned column header matching [`Bencher::bench`]'s rows.
    pub fn print_header() {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "mean", "p50", "p99", "min"
        );
        println!("{}", "-".repeat(104));
    }

    /// Write every collected result to `path` as a JSON array of rows.
    pub fn dump_json(&self, path: &str) -> std::io::Result<()> {
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string())
    }
}

fn format_row(r: &BenchResult) -> String {
    format!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns),
        fmt_ns(r.min_ns)
    )
}

/// Human-readable duration with auto-scaled unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding benched computations.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig { warmup_iters: 1, iters: 10, max_time: Duration::from_secs(5) };
        let mut b = Bencher::new(cfg);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn dump_json_writes() {
        let dir = std::env::temp_dir().join("raas_bench_test.json");
        let cfg = BenchConfig { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(1) };
        let mut b = Bencher::new(cfg);
        b.bench("x", || 1 + 1);
        b.dump_json(dir.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(dir);
    }
}
