//! Calibration profiles: four model personae × three dataset personae,
//! qualitatively matched to the paper's Figure 1(b) length CDFs and the
//! Figure 6 dense ceilings.

/// How a (simulated) reasoning model attends and derails.
#[derive(Debug, Clone, Copy)]
pub struct ModelProfile {
    /// Persona name (matches the paper's model list).
    pub name: &'static str,
    /// Dense-accuracy ceiling per dataset, indexed by `DatasetProfile.idx`
    /// (gsm8k, math500, aime) — paper Figure 6 top row ≈ these.
    pub base_acc: [f64; 3],
    /// Log-normal (mu, sigma) of tokens per reasoning sentence/step.
    pub step_tokens: (f64, f64),
    /// Attention mass on the milestone page while it is being consumed.
    pub milestone_hot: f64,
    /// Attention mass on the phoenix (prompt operand) page while consumed.
    pub phoenix_hot: f64,
    /// Per-step decay of a faded milestone's residual mass (the waterfall).
    pub decay: f64,
    /// Total background mass spread over all other pages.
    pub noise: f64,
    /// Extra decode steps on a derailment, log-normal (mu, sigma).
    pub derail_extra: (f64, f64),
    /// Probability a derailment loops until the decode cap (Figure 8).
    pub stuck_p: f64,
    /// Multiplicative log-normal noise on the *estimated* page scores the
    /// policies see (representative keys are an approximation; Quest/RaaS
    /// mis-rank pages occasionally, as on real attention).
    pub est_noise: f64,
}

/// Task shape per dataset.
#[derive(Debug, Clone, Copy)]
pub struct DatasetProfile {
    /// Dataset name (gsm8k, math500, aime).
    pub name: &'static str,
    /// Index into [`ModelProfile::base_acc`].
    pub idx: usize,
    /// Reasoning chain length (min, max) in steps.
    pub steps: (usize, usize),
    /// Max lookback distance (in steps) of milestone consumption.
    pub lookback: usize,
    /// Prompt length = base + per_step * k tokens.
    pub base_prompt: usize,
    /// Per-step prompt growth (see `base_prompt`).
    pub prompt_per_step: usize,
}

/// The four simulated model personae (paper Figure 1(b) / Figure 6 rows).
pub const MODELS: [ModelProfile; 4] = [
    ModelProfile {
        name: "marco-o1",
        base_acc: [0.90, 0.62, 0.16],
        step_tokens: (2.95, 0.32), // verbose ~20-token sentences
        milestone_hot: 0.30,
        phoenix_hot: 0.12,
        decay: 0.60,
        noise: 0.005,
        derail_extra: (2.2, 0.6),
        stuck_p: 0.35,
        est_noise: 0.35,
    },
    ModelProfile {
        name: "qwen2.5-math-7b",
        base_acc: [0.93, 0.70, 0.20],
        step_tokens: (2.80, 0.30),
        milestone_hot: 0.34,
        phoenix_hot: 0.14,
        decay: 0.55,
        noise: 0.004,
        derail_extra: (2.0, 0.6),
        stuck_p: 0.30,
        est_noise: 0.30,
    },
    ModelProfile {
        name: "mistral-math-7b",
        base_acc: [0.84, 0.52, 0.10],
        step_tokens: (2.75, 0.35),
        milestone_hot: 0.26,
        phoenix_hot: 0.10,
        decay: 0.62,
        noise: 0.008, // noisier attention
        derail_extra: (2.3, 0.7),
        stuck_p: 0.40,
        est_noise: 0.45,
    },
    ModelProfile {
        name: "deepscaler-1.5b",
        base_acc: [0.87, 0.64, 0.24],
        step_tokens: (3.05, 0.35), // RL-trained long chains
        milestone_hot: 0.28,
        phoenix_hot: 0.11,
        decay: 0.58,
        noise: 0.006,
        derail_extra: (2.5, 0.7),
        stuck_p: 0.45,
        est_noise: 0.40,
    },
];

/// The three simulated benchmark personae (paper Figure 6 columns).
pub const DATASETS: [DatasetProfile; 3] = [
    DatasetProfile {
        name: "gsm8k",
        idx: 0,
        steps: (4, 10),
        lookback: 4,
        base_prompt: 48,
        prompt_per_step: 2,
    },
    DatasetProfile {
        name: "math500",
        idx: 1,
        steps: (8, 22),
        lookback: 6,
        base_prompt: 64,
        prompt_per_step: 2,
    },
    DatasetProfile {
        name: "aime",
        idx: 2,
        steps: (16, 40),
        lookback: 7,
        base_prompt: 88,
        prompt_per_step: 2,
    },
];

/// Look up a model persona by its exact name.
pub fn model_by_name(name: &str) -> Option<ModelProfile> {
    MODELS.iter().find(|m| m.name == name).copied()
}
/// Look up a dataset persona by its exact name.
pub fn dataset_by_name(name: &str) -> Option<DatasetProfile> {
    DATASETS.iter().find(|d| d.name == name).copied()
}

/// A Lil (arXiv:2601.03043) very-long-decode scenario: the milestone
/// cadence and distractor pressure of an 8k–32k reasoning trace.
///
/// Two shapes matter for where a policy's accuracy cliff sits:
///
/// * **milestone-dense** (`era_steps == 1`): almost every step consumes a
///   recently emitted milestone at a short lookback — retention pressure
///   is shallow but constant.
/// * **milestone-sparse** (`era_steps > 1`): the chain anchors on one
///   milestone per era and re-reads it every `consume_every` steps until
///   the era ends — a few pages must survive deep into the decode while
///   thousands of distractor tokens churn past.
#[derive(Debug, Clone, Copy)]
pub struct LilScenario {
    /// Scenario name (`milestone-dense`, `milestone-sparse`).
    pub name: &'static str,
    /// A consuming step re-reads its operand every this many steps.
    pub consume_every: usize,
    /// Steps per era (1 = fresh short-lookback milestone per step).
    pub era_steps: usize,
    /// Max lookback (in steps) of milestone-dense consumption.
    pub lookback: usize,
    /// Prompt length in tokens (pinned; holds the phoenix operands).
    pub prompt_tokens: usize,
    /// Every this many steps, a step re-reads its phoenix operand.
    pub phoenix_every: usize,
    /// Per-token probability that a resident page flares (spurious
    /// attention spike).  Flare pressure scales with the resident-set
    /// size — the long-decode failure mode of selection over O(N) caches.
    pub flare_p: f64,
    /// Attention mass a flare adds to its page.
    pub flare_hot: f64,
    /// Dense-reference accuracy ceiling of the scenario.
    pub base_acc: f64,
    /// Probability a milestone miss still recovers the right answer.
    pub milestone_survive_p: f64,
    /// Probability a phoenix miss still recovers the right answer.
    pub phoenix_survive_p: f64,
    /// RaaS alpha used by the accuracy-cliff harness: tuned above the
    /// scenario's background mass AND its faded waterfall residuals (the
    /// default 1e-4 sits below `noise/n` at long decode, which would stamp
    /// every page every step; an alpha below the residual tail keeps cold
    /// pages stamp-fresh for ~50 tokens, blurring the recency signal the
    /// eviction ranking needs once flares churn the rest of the cache).
    pub raas_alpha: f64,
}

/// Decode-length grid of the accuracy-cliff bench (tokens).
pub const LIL_DECODE_LENS: [usize; 3] = [8192, 16384, 32768];

/// The two Lil trace shapes (see [`LilScenario`]).
pub const LIL_SCENARIOS: [LilScenario; 2] = [
    LilScenario {
        name: "milestone-dense",
        consume_every: 1,
        era_steps: 1,
        lookback: 4,
        prompt_tokens: 64,
        phoenix_every: 16,
        flare_p: 0.02,
        flare_hot: 0.20,
        base_acc: 0.82,
        milestone_survive_p: 0.60,
        phoenix_survive_p: 0.80,
        raas_alpha: 5e-3,
    },
    LilScenario {
        // The era anchor is re-read every step until the era ends: its
        // attention (and thus a stamp-refresh) recurs every ~17 tokens,
        // while a cold page goes ~flare_p^-1 tokens between spurious
        // flares — the recency gap RaaS's min-stamp eviction rides.
        name: "milestone-sparse",
        consume_every: 1,
        era_steps: 48,
        lookback: 48,
        prompt_tokens: 64,
        phoenix_every: 16,
        flare_p: 0.05,
        flare_hot: 0.20,
        base_acc: 0.82,
        milestone_survive_p: 0.60,
        phoenix_survive_p: 0.80,
        raas_alpha: 0.06,
    },
];

/// Look up a Lil scenario by its exact name.
pub fn lil_scenario_by_name(name: &str) -> Option<LilScenario> {
    LIL_SCENARIOS.iter().find(|s| s.name == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookups() {
        assert_eq!(model_by_name("marco-o1").unwrap().name, "marco-o1");
        assert_eq!(dataset_by_name("aime").unwrap().idx, 2);
        assert!(model_by_name("gpt-5").is_none());
    }

    #[test]
    fn ceilings_ordered_by_difficulty() {
        for m in MODELS {
            assert!(m.base_acc[0] > m.base_acc[1]);
            assert!(m.base_acc[1] > m.base_acc[2]);
        }
    }

    #[test]
    fn attention_mass_budgets_sane() {
        for m in MODELS {
            assert!(m.milestone_hot + m.phoenix_hot + m.noise < 0.6);
            assert!(m.decay > 0.0 && m.decay < 1.0);
            assert!(m.est_noise >= 0.0);
        }
    }

    #[test]
    fn lil_scenarios_sane() {
        assert_eq!(lil_scenario_by_name("milestone-sparse").unwrap().era_steps, 48);
        assert!(lil_scenario_by_name("milestone-cheap").is_none());
        for sc in LIL_SCENARIOS {
            assert!(sc.consume_every >= 1 && sc.era_steps >= 1);
            assert!(sc.flare_p >= 0.0 && sc.flare_p < 0.5);
            assert!(sc.base_acc > 0.0 && sc.base_acc < 1.0);
            assert!(sc.raas_alpha > 0.0);
            assert_eq!(sc.prompt_tokens % 16, 0, "prompt fills whole pages");
        }
        // the grid is sorted and strictly long-decode
        assert!(LIL_DECODE_LENS.windows(2).all(|w| w[0] < w[1]));
        assert!(LIL_DECODE_LENS[0] >= 8192);
    }
}
