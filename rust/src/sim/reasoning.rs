//! Reasoning-chain simulation: drives the real sparsity policies over
//! synthesised waterfall/phoenix attention traces and scores the outcome.
//!
//! One trial = one problem: a chain of `k` reasoning steps; step `i`
//! consumes the milestone emitted by step `r_i` (lookback ≤ L steps) and a
//! phoenix operand from the prompt.  Per decode token the simulator
//! synthesises page-level attention probabilities (the structure of paper
//! Figure 3), feeds them to the policy exactly as the engine feeds
//! estimated rep-scores, enforces the cache budget by eviction, and checks
//! *visibility* of required pages at consumption time: a required page is
//! visible iff it is both resident AND inside the step's selection.  For
//! eviction-sparse policies (Dense/Sink/H2O/RaaS/RPC) the selection is the
//! full resident set, so visibility reduces to residency; for
//! selection-sparse policies (Quest/LessIsMore) everything stays resident
//! and visibility is decided by the top-L pick.
//!
//! A missed milestone derails the chain (extra re-derivation steps, chance
//! of looping to the decode cap — Figure 8) and usually costs the answer;
//! a missed phoenix operand usually costs the answer.
//!
//! The Lil harness (`gen_lil_trace`/`run_lil_trial`) layers very-long
//! decodes (8k–32k) on the same machinery: pre-generated traces replayed
//! under every policy, with per-page attention flares so distractor
//! pressure grows with the resident set — the accuracy-cliff workload of
//! `benches/accuracy_cliff.rs`.

use crate::kvcache::page::{PageMeta, NO_POOL};
use crate::kvcache::policy::{resident_tokens, SparsityPolicy};
use crate::sim::profiles::{DatasetProfile, LilScenario, ModelProfile};
use crate::util::rng::Rng;

/// Simulator knobs shared by every trial (mirrors `EngineConfig`).
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// Cache budget in tokens (the paper's L).
    pub budget_tokens: usize,
    /// KV page size in tokens.
    pub page_size: usize,
    /// Hard decode-length cap (paper Figure 8 uses 4k).
    pub max_decode: usize,
    /// Pin prefill pages (RaaS idea #2); the ablation switch.
    pub pin_prefill: bool,
    /// Probability a milestone miss still recovers the right answer.
    pub milestone_survive_p: f64,
    /// Probability a phoenix miss still recovers the right answer.
    pub phoenix_survive_p: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            budget_tokens: 256,
            page_size: 16,
            max_decode: 4096,
            pin_prefill: true,
            milestone_survive_p: 0.15,
            phoenix_survive_p: 0.40,
        }
    }
}

/// What one simulated problem produced.
#[derive(Debug, Clone, Default)]
pub struct TrialOutcome {
    /// Whether the final answer came out right.
    pub correct: bool,
    /// Decode length in tokens (inflated by derailments).
    pub decode_len: usize,
    /// Whether decoding looped until the cap (paper Figure 8).
    pub hit_cap: bool,
    /// Milestone pages invisible at consumption time.
    pub milestone_misses: usize,
    /// Phoenix (prompt-operand) pages invisible at consumption time.
    pub phoenix_misses: usize,
    /// High-water resident KV in tokens (per-layer equivalent).
    pub peak_resident_tokens: usize,
}

/// Means over a batch of trials (one Figure-6/8/9 grid cell).
#[derive(Debug, Clone, Default)]
pub struct AggregateOutcome {
    /// Trials aggregated.
    pub trials: usize,
    /// Fraction of trials answering correctly.
    pub accuracy: f64,
    /// Mean decode length in tokens.
    pub mean_decode_len: f64,
    /// Fraction of trials that hit the decode cap.
    pub cap_rate: f64,
    /// Mean milestone misses per trial.
    pub milestone_miss_rate: f64,
    /// Mean phoenix misses per trial.
    pub phoenix_miss_rate: f64,
    /// Mean per-trial peak resident tokens.
    pub mean_peak_resident: f64,
}

/// Simulator-side page table: mirrors what the engine's SeqCache tracks,
/// plus ground-truth annotations for score synthesis.
struct SimCache {
    table: Vec<PageMeta>,
    /// For each page: milestones (chain step, emit decode-step) it contains.
    milestones: Vec<Vec<(usize, u64)>>,
    /// For each page: chain steps whose phoenix operand it contains.
    phoenixes: Vec<Vec<usize>>,
    page_size: usize,
    evicted_milestones: Vec<bool>, // indexed by chain step
    evicted_phoenixes: Vec<bool>,
}

impl SimCache {
    fn new(page_size: usize, k: usize) -> Self {
        SimCache {
            table: Vec::new(),
            milestones: Vec::new(),
            phoenixes: Vec::new(),
            page_size,
            evicted_milestones: vec![false; k + 1],
            evicted_phoenixes: vec![false; k + 1],
        }
    }

    fn append_token(&mut self, pos: usize, pinned: bool, now: u64) {
        let need_new = match self.table.last() {
            None => true,
            Some(p) => p.len >= self.page_size || p.pinned != pinned,
        };
        if need_new {
            self.table.push(PageMeta::new(NO_POOL, pos, pinned, now));
            self.milestones.push(Vec::new());
            self.phoenixes.push(Vec::new());
        }
        self.table.last_mut().unwrap().len += 1;
    }

    fn active(&self) -> usize {
        self.table.len() - 1
    }

    fn tag_milestone(&mut self, step: usize, emit_step: u64) {
        let idx = self.active();
        self.milestones[idx].push((step, emit_step));
    }

    /// Resident page index containing milestone of `step`, if any.
    fn milestone_page(&self, step: usize) -> Option<usize> {
        self.milestones.iter().position(|ms| ms.iter().any(|&(s, _)| s == step))
    }
    fn phoenix_page(&self, step: usize) -> Option<usize> {
        self.phoenixes.iter().position(|ps| ps.contains(&step))
    }

    fn evict(&mut self, idx: usize) {
        for &(s, _) in &self.milestones[idx] {
            self.evicted_milestones[s] = true;
        }
        for &s in &self.phoenixes[idx] {
            self.evicted_phoenixes[s] = true;
        }
        self.table.remove(idx);
        self.milestones.remove(idx);
        self.phoenixes.remove(idx);
    }

    /// Synthesize this decode-token's page attention probabilities.
    ///
    /// `consuming`: (milestone page, phoenix page) of the current chain step.
    #[allow(clippy::too_many_arguments)]
    fn synth_probs(&self, mp: &ModelProfile, now: u64, consuming_ms: Option<usize>,
                   consuming_ph: Option<usize>, probs: &mut Vec<f32>) {
        let n = self.table.len();
        probs.clear();
        probs.resize(n, 0.0);
        if n == 0 {
            return;
        }
        let bg = mp.noise as f32 / n as f32;
        for i in 0..n {
            probs[i] = bg;
            // waterfall residual of faded milestones
            for &(_, emit) in &self.milestones[i] {
                let age = now.saturating_sub(emit) as f64;
                probs[i] += (mp.milestone_hot * mp.decay.powf(age / 8.0)) as f32 * 0.5;
            }
        }
        probs[0] += 0.05; // sink
        let active = n - 1;
        probs[active] += 0.35;
        if let Some(i) = consuming_ms {
            probs[i] += mp.milestone_hot as f32;
        }
        if let Some(i) = consuming_ph {
            probs[i] += mp.phoenix_hot as f32;
        }
        let sum: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
    }
}

/// Run one simulated problem under `policy`.
pub fn run_trial(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                 dp: &DatasetProfile, rng: &mut Rng) -> TrialOutcome {
    let k = rng.range(dp.steps.0, dp.steps.1 + 1);
    let prompt_len = dp.base_prompt + dp.prompt_per_step * k;
    let mut cache = SimCache::new(params.page_size, k);
    let mut out = TrialOutcome::default();

    // ---- prefill: pinned pages; phoenix operands spread over the prompt ---
    for pos in 0..prompt_len {
        cache.append_token(pos, params.pin_prefill, 0);
        // operand for step i sits at a deterministic prompt offset
    }
    for step in 1..=k {
        // retroactively tag the prompt page holding step's operand
        let pos = (3 + 4 * (step - 1) + 3).min(prompt_len - 1);
        let page = (pos / params.page_size).min(cache.phoenixes.len() - 1);
        cache.phoenixes[page].push(step);
    }

    // chain structure
    let lookbacks: Vec<usize> = (1..=k)
        .map(|i| {
            let lo = i.saturating_sub(dp.lookback).max(0);
            rng.range(lo, i) // consume v_r with r in [lo, i)
        })
        .collect();

    // ---- decode ------------------------------------------------------------
    let mut pos = prompt_len;
    let mut now: u64 = 0;
    let mut probs: Vec<f32> = Vec::new();
    // reusable selection scratch, matching the engine's decode paths
    // (`select_into` instead of the allocating `select` wrapper)
    let mut sel: Vec<usize> = Vec::new();
    let mut pending: Vec<usize> = (1..=k).collect(); // chain steps left
    let mut emitted_ok = vec![false; k + 1];
    emitted_ok[0] = true; // v_0 comes from the prompt

    'outer: while let Some(step) = pending.first().copied() {
        pending.remove(0);
        let r = lookbacks[step - 1];
        let step_len = rng.lognormal(mp.step_tokens.0, mp.step_tokens.1).round().max(3.0) as usize;

        // visibility check happens mid-step, when the consumed operands are read
        let consume_at = step_len / 2;
        let mut ms_missed = false;
        let mut ph_missed = false;

        for t in 0..step_len {
            if out.decode_len >= params.max_decode {
                out.hit_cap = true;
                break 'outer;
            }
            now += 1;
            out.decode_len += 1;

            let consuming = t >= consume_at;
            let ms_page = if r > 0 { cache.milestone_page(r) } else { None };
            let ph_page = cache.phoenix_page(step);
            cache.synth_probs(mp, now, if consuming { ms_page } else { None },
                              if consuming { ph_page } else { None }, &mut probs);

            // The policy sees *estimated* scores: true attention perturbed by
            // multiplicative noise (representative keys are an approximation).
            let est: Vec<f32> = probs
                .iter()
                .map(|&p| p * ((mp.est_noise * rng.normal()).exp() as f32))
                .collect();
            policy.select_into(&cache.table, &est, params.budget_tokens, params.page_size,
                               &mut sel);

            if t == consume_at {
                // milestone of step r needed (unless it comes from the prompt)
                if r > 0 {
                    // resident AND selected — identity-selection policies
                    // always select every resident page, so this is purely
                    // a residency test for them
                    let visible = matches!(ms_page, Some(i) if sel.contains(&i));
                    if !visible && emitted_ok[r] {
                        ms_missed = true;
                    }
                }
                let ph_visible = matches!(ph_page, Some(i) if sel.contains(&i));
                if !ph_visible {
                    ph_missed = true;
                }
            }

            // observation uses the (renormalised) estimated probabilities —
            // RaaS thresholds what the rep-keys report, not ground truth
            let est_sum: f32 = est.iter().sum();
            let est_probs: Vec<f32> = est.iter().map(|&e| e / est_sum.max(1e-30)).collect();
            policy.observe(&mut cache.table, &est_probs, now);
            cache.append_token(pos, false, now);
            pos += 1;

            // budget enforcement
            while resident_tokens(&cache.table) > params.budget_tokens {
                match policy.evict_candidate(&cache.table) {
                    Some(idx) => cache.evict(idx),
                    None => break,
                }
            }
            out.peak_resident_tokens = out.peak_resident_tokens.max(resident_tokens(&cache.table));
        }

        // milestone for this step emitted at the step's final token
        cache.tag_milestone(step, now);
        emitted_ok[step] = true;

        if ms_missed {
            out.milestone_misses += 1;
            // derailment: re-derivation steps (Figure 8)
            if rng.chance(mp.stuck_p) {
                // model loses track and loops until the cap
                while out.decode_len < params.max_decode {
                    now += 1;
                    out.decode_len += 1;
                    // still exercises the cache so memory accounting holds
                    cache.synth_probs(mp, now, None, None, &mut probs);
                    policy.observe(&mut cache.table, &probs, now);
                    cache.append_token(pos, false, now);
                    pos += 1;
                    while resident_tokens(&cache.table) > params.budget_tokens {
                        match policy.evict_candidate(&cache.table) {
                            Some(idx) => cache.evict(idx),
                            None => break,
                        }
                    }
                }
                out.hit_cap = true;
                break 'outer;
            } else {
                let extra = rng.lognormal(mp.derail_extra.0, mp.derail_extra.1).round() as usize;
                for _ in 0..extra.min(params.max_decode.saturating_sub(out.decode_len)) {
                    now += 1;
                    out.decode_len += 1;
                    cache.synth_probs(mp, now, None, None, &mut probs);
                    policy.observe(&mut cache.table, &probs, now);
                    cache.append_token(pos, false, now);
                    pos += 1;
                    while resident_tokens(&cache.table) > params.budget_tokens {
                        match policy.evict_candidate(&cache.table) {
                            Some(idx) => cache.evict(idx),
                            None => break,
                        }
                    }
                }
            }
        }
        if ph_missed {
            out.phoenix_misses += 1;
        }
        out.peak_resident_tokens = out.peak_resident_tokens.max(resident_tokens(&cache.table));
    }

    // compose the answer probability
    let mut p_correct = mp.base_acc[dp.idx];
    for _ in 0..out.milestone_misses {
        p_correct *= params.milestone_survive_p;
    }
    for _ in 0..out.phoenix_misses {
        p_correct *= params.phoenix_survive_p;
    }
    if out.hit_cap {
        p_correct = 0.0; // never produced an answer (paper Figure 8)
    }
    out.correct = rng.chance(p_correct);
    out
}

/// Run `n` trials and aggregate.
pub fn run_trials(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                  dp: &DatasetProfile, n: usize, rng: &mut Rng) -> AggregateOutcome {
    let mut agg = AggregateOutcome { trials: n, ..Default::default() };
    let mut ms_den = 0usize;
    for _ in 0..n {
        let t = run_trial(policy, params, mp, dp, rng);
        agg.accuracy += t.correct as usize as f64;
        agg.mean_decode_len += t.decode_len as f64;
        agg.cap_rate += t.hit_cap as usize as f64;
        agg.milestone_miss_rate += t.milestone_misses as f64;
        agg.phoenix_miss_rate += t.phoenix_misses as f64;
        agg.mean_peak_resident += t.peak_resident_tokens as f64;
        ms_den += 1;
    }
    let n = ms_den as f64;
    agg.accuracy /= n;
    agg.mean_decode_len /= n;
    agg.cap_rate /= n;
    agg.milestone_miss_rate /= n;
    agg.phoenix_miss_rate /= n;
    agg.mean_peak_resident /= n;
    agg
}

// ---------------------------------------------------------------------------
// Lil: very-long-decode accuracy-cliff harness (arXiv:2601.03043 shape)
// ---------------------------------------------------------------------------

/// One step of a pre-generated Lil trace (see [`gen_lil_trace`]).
#[derive(Debug, Clone, Copy)]
pub struct LilStep {
    /// Chain step whose milestone this step consumes (0 = none).
    pub reads: usize,
    /// Whether this step re-reads its phoenix (prompt) operand.
    pub phoenix: bool,
    /// Tokens this step decodes.
    pub len: usize,
}

/// A pre-generated very-long-decode problem instance.  The *same* trace is
/// replayed under every policy (and under the unbudgeted dense reference),
/// so accuracy and token agreement are paired comparisons: a policy can
/// differ from dense only through visibility misses, never through RNG
/// drift.
#[derive(Debug, Clone)]
pub struct LilTrace {
    /// Prompt length in tokens (pinned pages holding phoenix operands).
    pub prompt_len: usize,
    /// The chain, in order.
    pub steps: Vec<LilStep>,
    /// Shared answer coin: the final answer is correct iff
    /// `answer_u < p_correct`.  Dense never misses, so its accuracy over a
    /// trace batch is *exactly* `count(answer_u < base_acc) / n` — the
    /// pinned reference the bench asserts against.
    pub answer_u: f64,
    /// Seed of the per-replay noise stream (estimation noise, flares,
    /// derailment lengths) — deterministic per (policy, trace).
    pub noise_seed: u64,
}

impl LilTrace {
    /// Decode length of the trace with no derailments, in tokens.
    pub fn nominal_len(&self) -> usize {
        self.steps.iter().map(|s| s.len).sum()
    }
}

/// Generate one Lil trace of at least `target_decode` nominal tokens under
/// scenario `sc` with `mp`'s step-length distribution.
pub fn gen_lil_trace(sc: &LilScenario, mp: &ModelProfile, target_decode: usize, rng: &mut Rng)
                     -> LilTrace {
    let mut steps = Vec::new();
    let mut total = 0usize;
    let mut i = 0usize;
    let mut era_anchor = 0usize;
    while total < target_decode {
        i += 1;
        let era_pos = (i - 1) % sc.era_steps.max(1);
        if sc.era_steps > 1 && era_pos == 0 {
            // a new era anchors on the milestone this step emits
            era_anchor = i;
        }
        let reads = if sc.era_steps <= 1 {
            // milestone-dense: short-lookback consumption of a recent step
            if i > 1 && i % sc.consume_every.max(1) == 0 {
                let back = sc.lookback.min(i - 1).max(1);
                i - rng.range(1, back + 1)
            } else {
                0
            }
        } else if era_pos > 0 && era_pos % sc.consume_every.max(1) == 0 {
            // milestone-sparse: keep re-reading the era's anchor
            era_anchor
        } else {
            0
        };
        let phoenix = i % sc.phoenix_every.max(1) == 0;
        let len = rng.lognormal(mp.step_tokens.0, mp.step_tokens.1).round().max(3.0) as usize;
        total += len;
        steps.push(LilStep { reads, phoenix, len });
    }
    LilTrace {
        prompt_len: sc.prompt_tokens,
        steps,
        answer_u: rng.f64(),
        noise_seed: rng.next_u64(),
    }
}

/// What one Lil trace replay produced.
#[derive(Debug, Clone, Default)]
pub struct LilOutcome {
    /// Whether the shared answer coin landed under this replay's
    /// `p_correct`.
    pub correct: bool,
    /// Decode length in tokens (inflated by derailments).
    pub decode_len: usize,
    /// Whether decoding looped until the cap.
    pub hit_cap: bool,
    /// Chain steps whose milestone was invisible at consumption.
    pub milestone_misses: usize,
    /// Chain steps whose phoenix operand was invisible at consumption.
    pub phoenix_misses: usize,
    /// Tokens of chain steps completed with every read visible — the
    /// numerator of token agreement vs the dense reference.
    pub visible_tokens: usize,
    /// High-water resident KV in tokens.
    pub peak_resident_tokens: usize,
}

/// Means over a batch of Lil traces (one accuracy-cliff grid cell).
#[derive(Debug, Clone, Default)]
pub struct LilAggregate {
    /// Traces replayed.
    pub trials: usize,
    /// Fraction of replays answering correctly.
    pub accuracy: f64,
    /// Mean `visible_tokens / max(decode_len, nominal_len)` — exactly 1.0
    /// for the unbudgeted dense reference, degraded by both misses and
    /// derailment inflation.
    pub token_agreement: f64,
    /// Mean decode length in tokens.
    pub mean_decode_len: f64,
    /// Fraction of replays that hit the decode cap.
    pub cap_rate: f64,
    /// Mean milestone misses per replay.
    pub milestone_miss_rate: f64,
    /// Mean phoenix misses per replay.
    pub phoenix_miss_rate: f64,
    /// Mean per-replay peak resident tokens.
    pub mean_peak_resident: f64,
}

/// Per-resident-page attention flares: each page spikes with probability
/// `flare_p` this token, then the distribution is renormalised.  Because
/// every resident page rolls independently, distractor pressure grows
/// with the resident-set size — selection over an O(N) cache faces ever
/// more flares as the decode lengthens, eviction-bounded caches do not.
fn add_flares(probs: &mut [f32], sc: &LilScenario, rng: &mut Rng) {
    if sc.flare_p <= 0.0 || probs.is_empty() {
        return;
    }
    let mut extra = 0.0f32;
    for p in probs.iter_mut() {
        if rng.chance(sc.flare_p) {
            *p += sc.flare_hot as f32;
            extra += sc.flare_hot as f32;
        }
    }
    if extra > 0.0 {
        let norm = 1.0 + extra;
        for p in probs.iter_mut() {
            *p /= norm;
        }
    }
}

/// Advance the cache one filler token (derailment re-derivation): no
/// consumption, same observe/append/evict cycle as a normal token.
fn lil_filler_token(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                    cache: &mut SimCache, probs: &mut Vec<f32>, pos: &mut usize, now: &mut u64) {
    *now += 1;
    cache.synth_probs(mp, *now, None, None, probs);
    policy.observe(&mut cache.table, probs, *now);
    cache.append_token(*pos, false, *now);
    *pos += 1;
    while resident_tokens(&cache.table) > params.budget_tokens {
        match policy.evict_candidate(&cache.table) {
            Some(idx) => cache.evict(idx),
            None => break,
        }
    }
}

/// Replay one Lil trace under `policy`.  Mirrors [`run_trial`]'s decode
/// loop (synth → estimate → select → visibility → observe → append →
/// evict) plus the scenario's attention flares; all randomness comes from
/// the trace's `noise_seed`, so a replay is deterministic per
/// (policy, trace).
pub fn run_lil_trial(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                     sc: &LilScenario, trace: &LilTrace) -> LilOutcome {
    let k = trace.steps.len();
    let mut cache = SimCache::new(params.page_size, k);
    let mut out = LilOutcome::default();
    let mut rng = Rng::new(trace.noise_seed);

    // pinned prompt; phoenix operands spread over it, one tag per step
    for pos in 0..trace.prompt_len {
        cache.append_token(pos, params.pin_prefill, 0);
    }
    for step in 1..=k {
        let pos = (7 * step) % trace.prompt_len.max(1);
        let page = (pos / params.page_size).min(cache.phoenixes.len() - 1);
        cache.phoenixes[page].push(step);
    }

    let mut pos = trace.prompt_len;
    let mut now: u64 = 0;
    let mut probs: Vec<f32> = Vec::new();
    let mut sel: Vec<usize> = Vec::new();
    let mut emitted = vec![false; k + 1];

    'outer: for (idx, st) in trace.steps.iter().enumerate() {
        let step = idx + 1;
        let consume_at = st.len / 2;
        let mut ms_missed = false;
        let mut ph_missed = false;
        for t in 0..st.len {
            if out.decode_len >= params.max_decode {
                out.hit_cap = true;
                break 'outer;
            }
            now += 1;
            out.decode_len += 1;

            let consuming = t >= consume_at;
            let ms_page = if st.reads > 0 { cache.milestone_page(st.reads) } else { None };
            let ph_page = if st.phoenix { cache.phoenix_page(step) } else { None };
            cache.synth_probs(mp, now, if consuming { ms_page } else { None },
                              if consuming { ph_page } else { None }, &mut probs);
            add_flares(&mut probs, sc, &mut rng);
            let est: Vec<f32> = probs
                .iter()
                .map(|&p| p * ((mp.est_noise * rng.normal()).exp() as f32))
                .collect();
            policy.select_into(&cache.table, &est, params.budget_tokens, params.page_size,
                               &mut sel);

            if t == consume_at {
                if st.reads > 0 && emitted[st.reads] {
                    let visible = matches!(ms_page, Some(i) if sel.contains(&i));
                    if !visible {
                        ms_missed = true;
                    }
                }
                if st.phoenix {
                    let visible = matches!(ph_page, Some(i) if sel.contains(&i));
                    if !visible {
                        ph_missed = true;
                    }
                }
            }

            let est_sum: f32 = est.iter().sum();
            let est_probs: Vec<f32> = est.iter().map(|&e| e / est_sum.max(1e-30)).collect();
            policy.observe(&mut cache.table, &est_probs, now);
            cache.append_token(pos, false, now);
            pos += 1;
            while resident_tokens(&cache.table) > params.budget_tokens {
                match policy.evict_candidate(&cache.table) {
                    Some(idx) => cache.evict(idx),
                    None => break,
                }
            }
            out.peak_resident_tokens =
                out.peak_resident_tokens.max(resident_tokens(&cache.table));
        }

        cache.tag_milestone(step, now);
        emitted[step] = true;
        if !ms_missed && !ph_missed {
            out.visible_tokens += st.len;
        }
        if ms_missed {
            out.milestone_misses += 1;
            if rng.chance(mp.stuck_p) {
                // loses track and loops to the cap (Figure-8 shape)
                while out.decode_len < params.max_decode {
                    out.decode_len += 1;
                    lil_filler_token(policy, params, mp, &mut cache, &mut probs, &mut pos,
                                     &mut now);
                }
                out.hit_cap = true;
                break 'outer;
            }
            let extra = rng.lognormal(mp.derail_extra.0, mp.derail_extra.1).round() as usize;
            for _ in 0..extra.min(params.max_decode.saturating_sub(out.decode_len)) {
                out.decode_len += 1;
                lil_filler_token(policy, params, mp, &mut cache, &mut probs, &mut pos, &mut now);
            }
        }
        if ph_missed {
            out.phoenix_misses += 1;
        }
    }

    let mut p_correct = sc.base_acc;
    for _ in 0..out.milestone_misses {
        p_correct *= sc.milestone_survive_p;
    }
    for _ in 0..out.phoenix_misses {
        p_correct *= sc.phoenix_survive_p;
    }
    if out.hit_cap {
        p_correct = 0.0;
    }
    out.correct = trace.answer_u < p_correct;
    out
}

/// Replay a batch of traces under `policy` and aggregate.  The batch is
/// generated once per grid cell and shared across policies (paired
/// comparison — see [`LilTrace`]).
pub fn run_lil_trials(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                      sc: &LilScenario, traces: &[LilTrace]) -> LilAggregate {
    let mut agg = LilAggregate { trials: traces.len(), ..Default::default() };
    for trace in traces {
        let t = run_lil_trial(policy, params, mp, sc, trace);
        let denom = t.decode_len.max(trace.nominal_len()).max(1) as f64;
        agg.accuracy += t.correct as usize as f64;
        agg.token_agreement += t.visible_tokens as f64 / denom;
        agg.mean_decode_len += t.decode_len as f64;
        agg.cap_rate += t.hit_cap as usize as f64;
        agg.milestone_miss_rate += t.milestone_misses as f64;
        agg.phoenix_miss_rate += t.phoenix_misses as f64;
        agg.mean_peak_resident += t.peak_resident_tokens as f64;
    }
    let n = traces.len().max(1) as f64;
    agg.accuracy /= n;
    agg.token_agreement /= n;
    agg.mean_decode_len /= n;
    agg.cap_rate /= n;
    agg.milestone_miss_rate /= n;
    agg.phoenix_miss_rate /= n;
    agg.mean_peak_resident /= n;
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, PolicyKind};
    use crate::kvcache::policy::make_policy;
    use crate::sim::profiles::{DATASETS, MODELS};

    fn agg_on(kind: PolicyKind, budget: usize, n: usize, ds: usize) -> AggregateOutcome {
        let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
        let policy = make_policy(&cfg);
        let params = SimParams { budget_tokens: budget, max_decode: 2048, ..Default::default() };
        let mut rng = Rng::new(99);
        run_trials(policy.as_ref(), &params, &MODELS[1], &DATASETS[ds], n, &mut rng)
    }

    fn agg(kind: PolicyKind, budget: usize, n: usize) -> AggregateOutcome {
        agg_on(kind, budget, n, 1)
    }

    #[test]
    fn dense_matches_ceiling() {
        let a = agg(PolicyKind::Dense, 1024, 150);
        assert!(a.milestone_miss_rate == 0.0 && a.phoenix_miss_rate == 0.0);
        assert!((a.accuracy - MODELS[1].base_acc[1]).abs() < 0.12,
                "dense accuracy {} vs ceiling {}", a.accuracy, MODELS[1].base_acc[1]);
    }

    #[test]
    fn raas_tracks_dense_at_moderate_budget() {
        let dense = agg(PolicyKind::Dense, 512, 120);
        let raas = agg(PolicyKind::Raas, 512, 120);
        assert!(raas.accuracy > dense.accuracy - 0.15,
                "raas {} vs dense {}", raas.accuracy, dense.accuracy);
    }

    #[test]
    fn sink_collapses_at_small_budget() {
        let sink = agg(PolicyKind::Sink, 128, 120);
        let raas = agg(PolicyKind::Raas, 128, 120);
        assert!(sink.accuracy < raas.accuracy + 0.05,
                "sink {} should not beat raas {}", sink.accuracy, raas.accuracy);
        assert!(sink.milestone_misses_nonzero(), "sink must lose milestones");
    }

    impl AggregateOutcome {
        fn milestone_misses_nonzero(&self) -> bool {
            self.milestone_miss_rate > 0.0
        }
    }

    #[test]
    fn raas_memory_bounded_quest_not() {
        // aime: longest chains — the O(N) vs O(L) gap is widest there
        let raas = agg_on(PolicyKind::Raas, 256, 60, 2);
        let quest = agg_on(PolicyKind::Quest, 256, 60, 2);
        // RaaS peak resident stays near the budget (prefill pinning may push
        // it slightly over); Quest grows with the decode length.
        assert!(raas.mean_peak_resident < 256.0 + 160.0,
                "raas peak {}", raas.mean_peak_resident);
        assert!(quest.mean_peak_resident > 1.5 * raas.mean_peak_resident,
                "quest {} vs raas {}", quest.mean_peak_resident, raas.mean_peak_resident);
    }

    #[test]
    fn h2o_small_budget_hits_cap_often() {
        let h2o = agg(PolicyKind::H2o, 128, 100);
        let dense = agg(PolicyKind::Dense, 128, 100);
        assert!(h2o.cap_rate > dense.cap_rate,
                "h2o cap {} vs dense {}", h2o.cap_rate, dense.cap_rate);
        assert!(h2o.mean_decode_len > dense.mean_decode_len);
    }

    #[test]
    fn budget_monotone_for_raas() {
        let small = agg(PolicyKind::Raas, 64, 100);
        let large = agg(PolicyKind::Raas, 1024, 100);
        assert!(large.accuracy >= small.accuracy - 0.05,
                "raas acc should improve with budget: {} -> {}", small.accuracy, large.accuracy);
    }

    use crate::sim::profiles::LIL_SCENARIOS;

    fn lil_traces(sc_idx: usize, target: usize, n: usize, seed: u64) -> Vec<LilTrace> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| gen_lil_trace(&LIL_SCENARIOS[sc_idx], &MODELS[2], target, &mut rng))
            .collect()
    }

    #[test]
    fn lil_trace_hits_target_length() {
        for trace in lil_traces(1, 2048, 5, 7) {
            assert!(trace.nominal_len() >= 2048, "nominal {}", trace.nominal_len());
            // one step of overshoot at most (~e^2.75 ≈ 16 tokens + tail)
            assert!(trace.nominal_len() < 2048 + 512);
            assert!((0.0..1.0).contains(&trace.answer_u));
            // every consumed milestone was emitted by an earlier step
            for (i, st) in trace.steps.iter().enumerate() {
                assert!(st.reads <= i, "step {} reads future step {}", i + 1, st.reads);
            }
        }
    }

    #[test]
    fn lil_dense_reference_is_exact() {
        let sc = &LIL_SCENARIOS[1];
        let traces = lil_traces(1, 2048, 20, 11);
        let cfg = EngineConfig { policy: PolicyKind::Dense, ..Default::default() };
        let policy = make_policy(&cfg);
        let params = SimParams {
            budget_tokens: 1 << 24,
            max_decode: 2048 + 4096,
            ..Default::default()
        };
        let agg = run_lil_trials(policy.as_ref(), &params, &MODELS[2], sc, &traces);
        // dense never misses and never derails: accuracy is EXACTLY the
        // answer-coin count and token agreement is exactly 1
        let expected =
            traces.iter().filter(|t| t.answer_u < sc.base_acc).count() as f64 / 20.0;
        assert!((agg.accuracy - expected).abs() < 1e-12, "{} vs {}", agg.accuracy, expected);
        assert!((agg.token_agreement - 1.0).abs() < 1e-12);
        assert_eq!(agg.milestone_miss_rate, 0.0);
        assert_eq!(agg.phoenix_miss_rate, 0.0);
        assert_eq!(agg.cap_rate, 0.0);
    }

    #[test]
    fn lil_policies_complete() {
        // every zoo policy replays a small trace without panicking, and
        // memory-bounding policies respect the budget
        let sc = &LIL_SCENARIOS[0];
        let traces = lil_traces(0, 512, 2, 13);
        let params = SimParams {
            budget_tokens: 256,
            max_decode: 512 + 2048,
            ..Default::default()
        };
        for kind in PolicyKind::all() {
            let cfg = EngineConfig {
                policy: kind,
                budget: 256,
                alpha: sc.raas_alpha,
                ..Default::default()
            };
            let policy = make_policy(&cfg);
            let agg = run_lil_trials(policy.as_ref(), &params, &MODELS[2], sc, &traces);
            assert_eq!(agg.trials, 2, "{kind:?}");
            assert!(agg.mean_decode_len > 0.0, "{kind:?}");
            if policy.bounds_memory() {
                assert!(agg.mean_peak_resident < 256.0 + 160.0,
                        "{kind:?} peak {}", agg.mean_peak_resident);
            }
        }
    }
}
