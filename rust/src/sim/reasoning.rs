//! Reasoning-chain simulation: drives the real sparsity policies over
//! synthesised waterfall/phoenix attention traces and scores the outcome.
//!
//! One trial = one problem: a chain of `k` reasoning steps; step `i`
//! consumes the milestone emitted by step `r_i` (lookback ≤ L steps) and a
//! phoenix operand from the prompt.  Per decode token the simulator
//! synthesises page-level attention probabilities (the structure of paper
//! Figure 3), feeds them to the policy exactly as the engine feeds
//! estimated rep-scores, enforces the cache budget by eviction, and checks
//! *visibility* of required pages at consumption time:
//!
//! * bounded policies (RaaS/Sink/H2O): required page still resident?
//! * Quest: required page inside the top-L selection?
//! * Dense: always visible.
//!
//! A missed milestone derails the chain (extra re-derivation steps, chance
//! of looping to the decode cap — Figure 8) and usually costs the answer;
//! a missed phoenix operand usually costs the answer.

use crate::config::PolicyKind;
use crate::kvcache::page::{PageMeta, NO_POOL};
use crate::kvcache::policy::{resident_tokens, SparsityPolicy};
use crate::sim::profiles::{DatasetProfile, ModelProfile};
use crate::util::rng::Rng;

/// Simulator knobs shared by every trial (mirrors `EngineConfig`).
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// Cache budget in tokens (the paper's L).
    pub budget_tokens: usize,
    /// KV page size in tokens.
    pub page_size: usize,
    /// Hard decode-length cap (paper Figure 8 uses 4k).
    pub max_decode: usize,
    /// Pin prefill pages (RaaS idea #2); the ablation switch.
    pub pin_prefill: bool,
    /// Probability a milestone miss still recovers the right answer.
    pub milestone_survive_p: f64,
    /// Probability a phoenix miss still recovers the right answer.
    pub phoenix_survive_p: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            budget_tokens: 256,
            page_size: 16,
            max_decode: 4096,
            pin_prefill: true,
            milestone_survive_p: 0.15,
            phoenix_survive_p: 0.40,
        }
    }
}

/// What one simulated problem produced.
#[derive(Debug, Clone, Default)]
pub struct TrialOutcome {
    /// Whether the final answer came out right.
    pub correct: bool,
    /// Decode length in tokens (inflated by derailments).
    pub decode_len: usize,
    /// Whether decoding looped until the cap (paper Figure 8).
    pub hit_cap: bool,
    /// Milestone pages invisible at consumption time.
    pub milestone_misses: usize,
    /// Phoenix (prompt-operand) pages invisible at consumption time.
    pub phoenix_misses: usize,
    /// High-water resident KV in tokens (per-layer equivalent).
    pub peak_resident_tokens: usize,
}

/// Means over a batch of trials (one Figure-6/8/9 grid cell).
#[derive(Debug, Clone, Default)]
pub struct AggregateOutcome {
    /// Trials aggregated.
    pub trials: usize,
    /// Fraction of trials answering correctly.
    pub accuracy: f64,
    /// Mean decode length in tokens.
    pub mean_decode_len: f64,
    /// Fraction of trials that hit the decode cap.
    pub cap_rate: f64,
    /// Mean milestone misses per trial.
    pub milestone_miss_rate: f64,
    /// Mean phoenix misses per trial.
    pub phoenix_miss_rate: f64,
    /// Mean per-trial peak resident tokens.
    pub mean_peak_resident: f64,
}

/// Simulator-side page table: mirrors what the engine's SeqCache tracks,
/// plus ground-truth annotations for score synthesis.
struct SimCache {
    table: Vec<PageMeta>,
    /// For each page: milestones (chain step, emit decode-step) it contains.
    milestones: Vec<Vec<(usize, u64)>>,
    /// For each page: chain steps whose phoenix operand it contains.
    phoenixes: Vec<Vec<usize>>,
    page_size: usize,
    evicted_milestones: Vec<bool>, // indexed by chain step
    evicted_phoenixes: Vec<bool>,
}

impl SimCache {
    fn new(page_size: usize, k: usize) -> Self {
        SimCache {
            table: Vec::new(),
            milestones: Vec::new(),
            phoenixes: Vec::new(),
            page_size,
            evicted_milestones: vec![false; k + 1],
            evicted_phoenixes: vec![false; k + 1],
        }
    }

    fn append_token(&mut self, pos: usize, pinned: bool, now: u64) {
        let need_new = match self.table.last() {
            None => true,
            Some(p) => p.len >= self.page_size || p.pinned != pinned,
        };
        if need_new {
            self.table.push(PageMeta::new(NO_POOL, pos, pinned, now));
            self.milestones.push(Vec::new());
            self.phoenixes.push(Vec::new());
        }
        self.table.last_mut().unwrap().len += 1;
    }

    fn active(&self) -> usize {
        self.table.len() - 1
    }

    fn tag_milestone(&mut self, step: usize, emit_step: u64) {
        let idx = self.active();
        self.milestones[idx].push((step, emit_step));
    }

    /// Resident page index containing milestone of `step`, if any.
    fn milestone_page(&self, step: usize) -> Option<usize> {
        self.milestones.iter().position(|ms| ms.iter().any(|&(s, _)| s == step))
    }
    fn phoenix_page(&self, step: usize) -> Option<usize> {
        self.phoenixes.iter().position(|ps| ps.contains(&step))
    }

    fn evict(&mut self, idx: usize) {
        for &(s, _) in &self.milestones[idx] {
            self.evicted_milestones[s] = true;
        }
        for &s in &self.phoenixes[idx] {
            self.evicted_phoenixes[s] = true;
        }
        self.table.remove(idx);
        self.milestones.remove(idx);
        self.phoenixes.remove(idx);
    }

    /// Synthesize this decode-token's page attention probabilities.
    ///
    /// `consuming`: (milestone page, phoenix page) of the current chain step.
    #[allow(clippy::too_many_arguments)]
    fn synth_probs(&self, mp: &ModelProfile, now: u64, consuming_ms: Option<usize>,
                   consuming_ph: Option<usize>, probs: &mut Vec<f32>) {
        let n = self.table.len();
        probs.clear();
        probs.resize(n, 0.0);
        if n == 0 {
            return;
        }
        let bg = mp.noise as f32 / n as f32;
        for i in 0..n {
            probs[i] = bg;
            // waterfall residual of faded milestones
            for &(_, emit) in &self.milestones[i] {
                let age = now.saturating_sub(emit) as f64;
                probs[i] += (mp.milestone_hot * mp.decay.powf(age / 8.0)) as f32 * 0.5;
            }
        }
        probs[0] += 0.05; // sink
        let active = n - 1;
        probs[active] += 0.35;
        if let Some(i) = consuming_ms {
            probs[i] += mp.milestone_hot as f32;
        }
        if let Some(i) = consuming_ph {
            probs[i] += mp.phoenix_hot as f32;
        }
        let sum: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
    }
}

/// Run one simulated problem under `policy`.
pub fn run_trial(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                 dp: &DatasetProfile, rng: &mut Rng) -> TrialOutcome {
    let k = rng.range(dp.steps.0, dp.steps.1 + 1);
    let prompt_len = dp.base_prompt + dp.prompt_per_step * k;
    let mut cache = SimCache::new(params.page_size, k);
    let mut out = TrialOutcome::default();

    // ---- prefill: pinned pages; phoenix operands spread over the prompt ---
    for pos in 0..prompt_len {
        cache.append_token(pos, params.pin_prefill, 0);
        // operand for step i sits at a deterministic prompt offset
    }
    for step in 1..=k {
        // retroactively tag the prompt page holding step's operand
        let pos = (3 + 4 * (step - 1) + 3).min(prompt_len - 1);
        let page = (pos / params.page_size).min(cache.phoenixes.len() - 1);
        cache.phoenixes[page].push(step);
    }

    // chain structure
    let lookbacks: Vec<usize> = (1..=k)
        .map(|i| {
            let lo = i.saturating_sub(dp.lookback).max(0);
            rng.range(lo, i) // consume v_r with r in [lo, i)
        })
        .collect();

    // ---- decode ------------------------------------------------------------
    let mut pos = prompt_len;
    let mut now: u64 = 0;
    let mut probs: Vec<f32> = Vec::new();
    // reusable selection scratch, matching the engine's decode paths
    // (`select_into` instead of the allocating `select` wrapper)
    let mut sel: Vec<usize> = Vec::new();
    let mut pending: Vec<usize> = (1..=k).collect(); // chain steps left
    let mut emitted_ok = vec![false; k + 1];
    emitted_ok[0] = true; // v_0 comes from the prompt

    'outer: while let Some(step) = pending.first().copied() {
        pending.remove(0);
        let r = lookbacks[step - 1];
        let step_len = rng.lognormal(mp.step_tokens.0, mp.step_tokens.1).round().max(3.0) as usize;

        // visibility check happens mid-step, when the consumed operands are read
        let consume_at = step_len / 2;
        let mut ms_missed = false;
        let mut ph_missed = false;

        for t in 0..step_len {
            if out.decode_len >= params.max_decode {
                out.hit_cap = true;
                break 'outer;
            }
            now += 1;
            out.decode_len += 1;

            let consuming = t >= consume_at;
            let ms_page = if r > 0 { cache.milestone_page(r) } else { None };
            let ph_page = cache.phoenix_page(step);
            cache.synth_probs(mp, now, if consuming { ms_page } else { None },
                              if consuming { ph_page } else { None }, &mut probs);

            // The policy sees *estimated* scores: true attention perturbed by
            // multiplicative noise (representative keys are an approximation).
            let est: Vec<f32> = probs
                .iter()
                .map(|&p| p * ((mp.est_noise * rng.normal()).exp() as f32))
                .collect();
            policy.select_into(&cache.table, &est, params.budget_tokens, params.page_size,
                               &mut sel);

            if t == consume_at {
                // milestone of step r needed (unless it comes from the prompt)
                if r > 0 {
                    let visible = match ms_page {
                        Some(i) => policy.kind() != PolicyKind::Quest || sel.contains(&i),
                        None => false,
                    };
                    if !visible && emitted_ok[r] {
                        ms_missed = true;
                    }
                }
                let ph_visible = match ph_page {
                    Some(i) => policy.kind() != PolicyKind::Quest || sel.contains(&i),
                    None => false,
                };
                if !ph_visible {
                    ph_missed = true;
                }
            }

            // observation uses the (renormalised) estimated probabilities —
            // RaaS thresholds what the rep-keys report, not ground truth
            let est_sum: f32 = est.iter().sum();
            let est_probs: Vec<f32> = est.iter().map(|&e| e / est_sum.max(1e-30)).collect();
            policy.observe(&mut cache.table, &est_probs, now);
            cache.append_token(pos, false, now);
            pos += 1;

            // budget enforcement
            while resident_tokens(&cache.table) > params.budget_tokens {
                match policy.evict_candidate(&cache.table) {
                    Some(idx) => cache.evict(idx),
                    None => break,
                }
            }
            out.peak_resident_tokens = out.peak_resident_tokens.max(resident_tokens(&cache.table));
        }

        // milestone for this step emitted at the step's final token
        cache.tag_milestone(step, now);
        emitted_ok[step] = true;

        if ms_missed {
            out.milestone_misses += 1;
            // derailment: re-derivation steps (Figure 8)
            if rng.chance(mp.stuck_p) {
                // model loses track and loops until the cap
                while out.decode_len < params.max_decode {
                    now += 1;
                    out.decode_len += 1;
                    // still exercises the cache so memory accounting holds
                    cache.synth_probs(mp, now, None, None, &mut probs);
                    policy.observe(&mut cache.table, &probs, now);
                    cache.append_token(pos, false, now);
                    pos += 1;
                    while resident_tokens(&cache.table) > params.budget_tokens {
                        match policy.evict_candidate(&cache.table) {
                            Some(idx) => cache.evict(idx),
                            None => break,
                        }
                    }
                }
                out.hit_cap = true;
                break 'outer;
            } else {
                let extra = rng.lognormal(mp.derail_extra.0, mp.derail_extra.1).round() as usize;
                for _ in 0..extra.min(params.max_decode.saturating_sub(out.decode_len)) {
                    now += 1;
                    out.decode_len += 1;
                    cache.synth_probs(mp, now, None, None, &mut probs);
                    policy.observe(&mut cache.table, &probs, now);
                    cache.append_token(pos, false, now);
                    pos += 1;
                    while resident_tokens(&cache.table) > params.budget_tokens {
                        match policy.evict_candidate(&cache.table) {
                            Some(idx) => cache.evict(idx),
                            None => break,
                        }
                    }
                }
            }
        }
        if ph_missed {
            out.phoenix_misses += 1;
        }
        out.peak_resident_tokens = out.peak_resident_tokens.max(resident_tokens(&cache.table));
    }

    // compose the answer probability
    let mut p_correct = mp.base_acc[dp.idx];
    for _ in 0..out.milestone_misses {
        p_correct *= params.milestone_survive_p;
    }
    for _ in 0..out.phoenix_misses {
        p_correct *= params.phoenix_survive_p;
    }
    if out.hit_cap {
        p_correct = 0.0; // never produced an answer (paper Figure 8)
    }
    out.correct = rng.chance(p_correct);
    out
}

/// Run `n` trials and aggregate.
pub fn run_trials(policy: &dyn SparsityPolicy, params: &SimParams, mp: &ModelProfile,
                  dp: &DatasetProfile, n: usize, rng: &mut Rng) -> AggregateOutcome {
    let mut agg = AggregateOutcome { trials: n, ..Default::default() };
    let mut ms_den = 0usize;
    for _ in 0..n {
        let t = run_trial(policy, params, mp, dp, rng);
        agg.accuracy += t.correct as usize as f64;
        agg.mean_decode_len += t.decode_len as f64;
        agg.cap_rate += t.hit_cap as usize as f64;
        agg.milestone_miss_rate += t.milestone_misses as f64;
        agg.phoenix_miss_rate += t.phoenix_misses as f64;
        agg.mean_peak_resident += t.peak_resident_tokens as f64;
        ms_den += 1;
    }
    let n = ms_den as f64;
    agg.accuracy /= n;
    agg.mean_decode_len /= n;
    agg.cap_rate /= n;
    agg.milestone_miss_rate /= n;
    agg.phoenix_miss_rate /= n;
    agg.mean_peak_resident /= n;
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, PolicyKind};
    use crate::kvcache::policy::make_policy;
    use crate::sim::profiles::{DATASETS, MODELS};

    fn agg_on(kind: PolicyKind, budget: usize, n: usize, ds: usize) -> AggregateOutcome {
        let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
        let policy = make_policy(&cfg);
        let params = SimParams { budget_tokens: budget, max_decode: 2048, ..Default::default() };
        let mut rng = Rng::new(99);
        run_trials(policy.as_ref(), &params, &MODELS[1], &DATASETS[ds], n, &mut rng)
    }

    fn agg(kind: PolicyKind, budget: usize, n: usize) -> AggregateOutcome {
        agg_on(kind, budget, n, 1)
    }

    #[test]
    fn dense_matches_ceiling() {
        let a = agg(PolicyKind::Dense, 1024, 150);
        assert!(a.milestone_miss_rate == 0.0 && a.phoenix_miss_rate == 0.0);
        assert!((a.accuracy - MODELS[1].base_acc[1]).abs() < 0.12,
                "dense accuracy {} vs ceiling {}", a.accuracy, MODELS[1].base_acc[1]);
    }

    #[test]
    fn raas_tracks_dense_at_moderate_budget() {
        let dense = agg(PolicyKind::Dense, 512, 120);
        let raas = agg(PolicyKind::Raas, 512, 120);
        assert!(raas.accuracy > dense.accuracy - 0.15,
                "raas {} vs dense {}", raas.accuracy, dense.accuracy);
    }

    #[test]
    fn sink_collapses_at_small_budget() {
        let sink = agg(PolicyKind::Sink, 128, 120);
        let raas = agg(PolicyKind::Raas, 128, 120);
        assert!(sink.accuracy < raas.accuracy + 0.05,
                "sink {} should not beat raas {}", sink.accuracy, raas.accuracy);
        assert!(sink.milestone_misses_nonzero(), "sink must lose milestones");
    }

    impl AggregateOutcome {
        fn milestone_misses_nonzero(&self) -> bool {
            self.milestone_miss_rate > 0.0
        }
    }

    #[test]
    fn raas_memory_bounded_quest_not() {
        // aime: longest chains — the O(N) vs O(L) gap is widest there
        let raas = agg_on(PolicyKind::Raas, 256, 60, 2);
        let quest = agg_on(PolicyKind::Quest, 256, 60, 2);
        // RaaS peak resident stays near the budget (prefill pinning may push
        // it slightly over); Quest grows with the decode length.
        assert!(raas.mean_peak_resident < 256.0 + 160.0,
                "raas peak {}", raas.mean_peak_resident);
        assert!(quest.mean_peak_resident > 1.5 * raas.mean_peak_resident,
                "quest {} vs raas {}", quest.mean_peak_resident, raas.mean_peak_resident);
    }

    #[test]
    fn h2o_small_budget_hits_cap_often() {
        let h2o = agg(PolicyKind::H2o, 128, 100);
        let dense = agg(PolicyKind::Dense, 128, 100);
        assert!(h2o.cap_rate > dense.cap_rate,
                "h2o cap {} vs dense {}", h2o.cap_rate, dense.cap_rate);
        assert!(h2o.mean_decode_len > dense.mean_decode_len);
    }

    #[test]
    fn budget_monotone_for_raas() {
        let small = agg(PolicyKind::Raas, 64, 100);
        let large = agg(PolicyKind::Raas, 1024, 100);
        assert!(large.accuracy >= small.accuracy - 0.05,
                "raas acc should improve with budget: {} -> {}", small.accuracy, large.accuracy);
    }
}
