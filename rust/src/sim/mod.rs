//! Trace-driven evaluation substrate.
//!
//! The paper's accuracy grids (Figures 6/8/9) run four 1.5B–7B reasoning
//! models over three math benchmarks — none of which exist in this offline
//! environment.  This module substitutes a **reasoning-trace simulator**
//! (DESIGN.md §3): it synthesises the decode-stage attention structure the
//! paper documents (waterfall milestones, phoenix prompt tokens, sink and
//! background mass) and drives the *real* policy implementations from
//! `kvcache::policy` against it, so the grids exercise exactly the code
//! that runs on the serving path.  The in-repo-trained tiny model validates
//! the same orderings end-to-end (`examples/budget_sweep.rs`).
//!
//! The Lil accuracy-cliff harness (`gen_lil_trace`/`run_lil_trials`)
//! extends the simulator to 8k–32k decodes with pre-generated traces
//! shared across policies, feeding `benches/accuracy_cliff.rs` and
//! `tests/accuracy_cliff.rs`.

pub mod profiles;
pub mod reasoning;

pub use profiles::{
    lil_scenario_by_name, DatasetProfile, LilScenario, ModelProfile, DATASETS, LIL_DECODE_LENS,
    LIL_SCENARIOS, MODELS,
};
pub use reasoning::{
    gen_lil_trace, run_lil_trial, run_lil_trials, run_trial, AggregateOutcome, LilAggregate,
    LilOutcome, LilStep, LilTrace, SimParams, TrialOutcome,
};
