//! Trace-driven evaluation substrate.
//!
//! The paper's accuracy grids (Figures 6/8/9) run four 1.5B–7B reasoning
//! models over three math benchmarks — none of which exist in this offline
//! environment.  This module substitutes a **reasoning-trace simulator**
//! (DESIGN.md §3): it synthesises the decode-stage attention structure the
//! paper documents (waterfall milestones, phoenix prompt tokens, sink and
//! background mass) and drives the *real* policy implementations from
//! `kvcache::policy` against it, so the grids exercise exactly the code
//! that runs on the serving path.  The in-repo-trained tiny model validates
//! the same orderings end-to-end (`examples/budget_sweep.rs`).

pub mod profiles;
pub mod reasoning;

pub use profiles::{DatasetProfile, ModelProfile, DATASETS, MODELS};
pub use reasoning::{run_trial, AggregateOutcome, SimParams, TrialOutcome};
