//! Prefill/TTFT benchmark (ISSUE 4 + ISSUE 5): monolithic vs streaming
//! chunked prefill at prompt lengths 64/512/2048, the serving-level
//! decode-stall comparison — what a co-scheduled decoder experiences while
//! a long prompt admits prefill-first (whole prompt, head-of-line
//! blocking) vs prefill-token-budgeted (Sarathi-style chunks) — plus the
//! concurrent-admission rows: TTFT with 2/4 co-admitted prompts under
//! sequential (one admission slot) vs concurrent (one slot per prompt)
//! chunked admission, and decode-stall percentiles under 4-way concurrent
//! prefill (the DESIGN.md §5 fairness claim: the per-tick token budget
//! caps prefill work regardless of how many prompts share it).
//!
//!     cargo bench --bench prefill_throughput              # full run
//!     cargo bench --bench prefill_throughput -- --test    # CI smoke
//!
//! Writes `results/BENCH_prefill.json` (uploaded by the CI bench-smoke
//! job).  Expected shape:
//!
//!  * chunked prefill throughput ≈ monolithic (the sim backend streams
//!    natively — no prefix recompute), while the prefill-phase KV staging
//!    buffer shrinks from O(prompt) to O(chunk)
//!    (`prefill_buffer_bytes` per row — no whole-prompt `PrefillOut` on
//!    the sim path);
//!  * under budgeted admission the max per-tick stall seen by co-scheduled
//!    decoders collapses from ~whole-prompt prefill time to ~one chunk,
//!    at a small TTFT cost for the long prompt itself.

use std::sync::mpsc::channel;
use std::time::Instant;

use raas::config::{ArtifactMeta, CorpusSpec, EngineConfig, PolicyKind};
use raas::coordinator::batcher::{Batcher, BatcherConfig};
use raas::coordinator::request::{Request, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::Engine;
use raas::util::json::Json;
use raas::util::stats::Summary;

const CHUNK: usize = 128;

fn mk_engine() -> Engine {
    let cfg = EngineConfig { policy: PolicyKind::Raas, budget: 192, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

/// A `len`-token prompt of plain digit tokens (content is irrelevant:
/// prefill cost scales with length only).
fn prompt_of(len: usize, spec: &CorpusSpec) -> Vec<u32> {
    (0..len).map(|i| spec.dig0 + (i % 10) as u32).collect()
}

/// One timed prefill: seq build + stream-to-pool + first token.
fn prefill_once(e: &mut Engine, prompt: &[u32], chunk: Option<usize>) -> f64 {
    let mut seq = e.new_seq();
    let t0 = Instant::now();
    match chunk {
        None => {
            e.prefill_seq(&mut seq, prompt).expect("prefill");
        }
        Some(c) => {
            let mut first = None;
            while first.is_none() {
                first = e.prefill_seq_partial(&mut seq, prompt, c).expect("prefill chunk");
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    e.release_seq(&mut seq);
    secs
}

/// Serving-level stall measurement: two decoders are mid-decode when
/// `n_long` `long_len`-token prompts arrive at once; admission uses
/// `concurrency` streaming slots.  Returns (per-tick wall times from
/// submission until every long prompt activated, time to the last
/// activation).
fn stall_run(budget: Option<usize>, concurrency: usize, n_long: usize, long_len: usize,
             spec: &CorpusSpec) -> (Vec<f64>, f64) {
    let engine = mk_engine();
    let mut b = Batcher::new(
        EngineBackend::new(engine),
        BatcherConfig {
            max_batch: 2 + n_long,
            prefill_token_budget: budget,
            prefill_concurrency: concurrency,
            ..Default::default()
        },
    );
    let (tx, _rx) = channel::<Response>();
    for id in 0..2u64 {
        // decoders outlive the measurement window
        b.submit(Request::new(id, prompt_of(8, spec), 100_000, tx.clone()));
    }
    // admit the decoders and take a few steady-state steps
    for _ in 0..3 {
        b.tick();
    }
    let t_submit = Instant::now();
    for i in 0..n_long as u64 {
        b.submit(Request::new(99 + i, prompt_of(long_len, spec), 2, tx.clone()));
    }
    let mut ticks = Vec::new();
    loop {
        let t0 = Instant::now();
        b.tick();
        ticks.push(t0.elapsed().as_secs_f64());
        let admitted = b
            .backend
            .engine
            .metrics
            .timer("admit.prefill_secs")
            .map(|t| t.count())
            .unwrap_or(0);
        if admitted >= 2 + n_long {
            return (ticks, t_submit.elapsed().as_secs_f64());
        }
        assert!(ticks.len() <= n_long * long_len + 16, "long prompts never admitted");
    }
}

/// Co-admission TTFT: one prompt per `lens` entry, submitted at once (in
/// order) under budgeted chunked admission with `concurrency` slots;
/// max_new 1, so each response's TTFT is (essentially) its JCT.  Returns
/// the per-request TTFTs (index-aligned with `lens`) and the makespan to
/// the last first-token.
fn coadmit_run(concurrency: usize, lens: &[usize], spec: &CorpusSpec) -> (Vec<f64>, f64) {
    let engine = mk_engine();
    let mut b = Batcher::new(
        EngineBackend::new(engine),
        BatcherConfig {
            max_batch: lens.len(),
            prefill_token_budget: Some(CHUNK),
            prefill_concurrency: concurrency,
            ..Default::default()
        },
    );
    let (tx, rx) = channel::<Response>();
    let t0 = Instant::now();
    for (id, &len) in lens.iter().enumerate() {
        b.submit(Request::new(id as u64, prompt_of(len, spec), 1, tx.clone()));
    }
    b.run_to_completion();
    let makespan = t0.elapsed().as_secs_f64();
    drop(tx);
    let mut ttfts = vec![0.0f64; lens.len()];
    let mut got = 0usize;
    for r in rx.iter() {
        assert!(r.error.is_none(), "co-admitted request failed");
        ttfts[r.id as usize] = r.ttft_secs;
        got += 1;
    }
    assert_eq!(got, lens.len());
    (ttfts, makespan)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let (warmup, iters) = if quick { (1, 3) } else { (2, 10) };
    let meta = ArtifactMeta::sim_default();
    let spec = meta.corpus.clone();
    let kv_dim = meta.model.n_kv_heads * meta.model.head_dim;
    let n_layers = meta.model.n_layers;
    // K + V staging floats, 4 bytes each, for a given chunk length
    let buffer_bytes = |chunk_len: usize| 2 * n_layers * chunk_len * kv_dim * 4;

    let mut rows: Vec<Json> = Vec::new();
    println!(
        "{:<28} {:>8} {:>8} {:>12} {:>14} {:>14}",
        "benchmark", "prompt", "chunk", "ttft", "tokens/sec", "buffer bytes"
    );
    println!("{}", "-".repeat(90));

    // ------------------------------------------------------------------
    // Raw prefill TTFT: monolithic (one whole-prompt chunk) vs streamed.
    // ------------------------------------------------------------------
    let mut rates: Vec<(usize, bool, f64)> = Vec::new();
    for &plen in &[64usize, 512, 2048] {
        let prompt = prompt_of(plen, &spec);
        for &chunked in &[false, true] {
            let mode = if chunked { "chunked" } else { "monolithic" };
            let chunk = if chunked { Some(CHUNK) } else { None };
            let mut e = mk_engine();
            for _ in 0..warmup {
                prefill_once(&mut e, &prompt, chunk);
            }
            let mut s = Summary::new();
            for _ in 0..iters {
                s.add(prefill_once(&mut e, &prompt, chunk));
            }
            let toks_per_sec = plen as f64 / s.mean();
            let buf =
                if chunked { buffer_bytes(CHUNK.min(plen)) } else { buffer_bytes(plen) };
            println!(
                "{:<28} {:>8} {:>8} {:>9.2} ms {:>14.0} {:>14}",
                format!("prefill/{mode}/p{plen}"),
                plen,
                if chunked { CHUNK } else { plen },
                s.mean() * 1e3,
                toks_per_sec,
                buf
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(format!("prefill/{mode}/p{plen}"))),
                ("mode", Json::str(mode)),
                ("prompt", Json::from(plen)),
                ("chunk", Json::from(if chunked { CHUNK } else { plen })),
                ("iters", Json::from(s.count())),
                ("ttft_mean_secs", Json::from(s.mean())),
                ("ttft_p50_secs", Json::from(s.percentile(50.0))),
                ("ttft_min_secs", Json::from(s.min())),
                ("tokens_per_sec", Json::from(toks_per_sec)),
                // prefill-phase KV staging buffer: O(chunk) streamed vs
                // O(prompt) monolithic — the copy-collapse evidence
                ("prefill_buffer_bytes", Json::from(buf)),
            ]));
            rates.push((plen, chunked, toks_per_sec));
        }
    }
    let rate = |plen: usize, chunked: bool| {
        rates
            .iter()
            .find(|&&(p, c, _)| p == plen && c == chunked)
            .map(|&(_, _, r)| r)
            .unwrap_or(f64::NAN)
    };
    println!();
    for &plen in &[64usize, 512, 2048] {
        let ratio = rate(plen, true) / rate(plen, false);
        let shrink = buffer_bytes(plen) as f64 / buffer_bytes(CHUNK.min(plen)) as f64;
        println!(
            "chunked vs monolithic @ p{plen}: {ratio:.2}x throughput, {shrink:.0}x smaller \
             staging buffer"
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("summary/p{plen}"))),
            ("prompt", Json::from(plen)),
            ("throughput_chunked_vs_monolithic", Json::from(ratio)),
            ("buffer_shrink_factor", Json::from(shrink)),
        ]));
    }

    // ------------------------------------------------------------------
    // Decode-stall under admission load (the Sarathi-style win).
    // ------------------------------------------------------------------
    let stall_iters: usize = if quick { 2 } else { 6 };
    println!(
        "\n{:<34} {:>8} {:>12} {:>12} {:>12}",
        "benchmark", "prompt", "max stall", "p99 stall", "long ttft"
    );
    println!("{}", "-".repeat(84));
    let mut stall_summary: Vec<(usize, bool, f64)> = Vec::new();
    for &plen in &[512usize, 2048] {
        for &budgeted in &[false, true] {
            let mode = if budgeted { "budgeted" } else { "prefill-first" };
            let budget = if budgeted { Some(CHUNK) } else { None };
            let mut all_ticks = Summary::new();
            let mut max_stall = Summary::new();
            let mut ttfts = Summary::new();
            for _ in 0..stall_iters {
                let (ticks, ttft) = stall_run(budget, 1, 1, plen, &spec);
                let worst = ticks.iter().cloned().fold(0.0f64, f64::max);
                max_stall.add(worst);
                all_ticks.extend(ticks);
                ttfts.add(ttft);
            }
            println!(
                "{:<34} {:>8} {:>9.2} ms {:>9.2} ms {:>9.2} ms",
                format!("stall/{mode}/p{plen}"),
                plen,
                max_stall.mean() * 1e3,
                all_ticks.percentile(99.0) * 1e3,
                ttfts.mean() * 1e3
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(format!("stall/{mode}/p{plen}"))),
                ("mode", Json::str(mode)),
                ("prompt", Json::from(plen)),
                ("prefill_token_budget", Json::from(if budgeted { CHUNK } else { 0 })),
                ("iters", Json::from(stall_iters)),
                // max decode-stall a co-scheduled decoder saw during the
                // long prompt's admission (mean over iters)
                ("decode_stall_max_secs", Json::from(max_stall.mean())),
                ("decode_stall_p50_secs", Json::from(all_ticks.percentile(50.0))),
                ("decode_stall_p99_secs", Json::from(all_ticks.percentile(99.0))),
                ("long_ttft_secs", Json::from(ttfts.mean())),
            ]));
            stall_summary.push((plen, budgeted, max_stall.mean()));
        }
    }
    let stall = |plen: usize, budgeted: bool| {
        stall_summary
            .iter()
            .find(|&&(p, b, _)| p == plen && b == budgeted)
            .map(|&(_, _, s)| s)
            .unwrap_or(f64::NAN)
    };
    println!();
    for &plen in &[512usize, 2048] {
        let ratio = stall(plen, false) / stall(plen, true);
        println!("decode-stall prefill-first vs budgeted @ p{plen}: {ratio:.1}x");
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("stall_summary/p{plen}"))),
            ("prompt", Json::from(plen)),
            ("stall_reduction_budgeted", Json::from(ratio)),
        ]));
    }

    // ------------------------------------------------------------------
    // Co-admitted prompts (ISSUE 5): one 512-token prompt submitted ahead
    // of (n-1) 64-token prompts, sequential (one admission slot) vs
    // concurrent (one slot per prompt) chunked admission.  Expected: the
    // short prompts' TTFT collapses under concurrency (they no longer
    // serialize behind the whole long prompt — the head-of-line blocking
    // the multi-slot Prefilling state removes), at a bounded TTFT cost
    // for the long prompt (it shares the per-tick budget), with makespan
    // — total budgeted prefill work — ~unchanged.
    // ------------------------------------------------------------------
    let co_iters: usize = if quick { 2 } else { 6 };
    println!(
        "\n{:<34} {:>8} {:>12} {:>12} {:>12}",
        "benchmark", "n", "short ttft", "long ttft", "makespan"
    );
    println!("{}", "-".repeat(84));
    let mut co_summary: Vec<(usize, bool, f64)> = Vec::new();
    for &n_co in &[2usize, 4] {
        let mut lens = vec![512usize];
        lens.extend(std::iter::repeat(64).take(n_co - 1));
        for &concurrent in &[false, true] {
            let mode = if concurrent { "concurrent" } else { "sequential" };
            let slots = if concurrent { n_co } else { 1 };
            let mut ttft_short = Summary::new();
            let mut ttft_long = Summary::new();
            let mut makespans = Summary::new();
            for _ in 0..co_iters {
                let (ttfts, makespan) = coadmit_run(slots, &lens, &spec);
                ttft_long.add(ttfts[0]);
                ttft_short.extend(ttfts[1..].to_vec());
                makespans.add(makespan);
            }
            println!(
                "{:<34} {:>8} {:>9.2} ms {:>9.2} ms {:>9.2} ms",
                format!("coadmit/{mode}/n{n_co}/long512_short64"),
                n_co,
                ttft_short.mean() * 1e3,
                ttft_long.mean() * 1e3,
                makespans.mean() * 1e3
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(format!("coadmit/{mode}/n{n_co}/long512_short64"))),
                ("mode", Json::str(mode)),
                ("co_admitted", Json::from(n_co)),
                ("prefill_concurrency", Json::from(slots)),
                ("long_prompt", Json::from(512usize)),
                ("short_prompt", Json::from(64usize)),
                ("iters", Json::from(co_iters)),
                ("ttft_short_mean_secs", Json::from(ttft_short.mean())),
                ("ttft_long_mean_secs", Json::from(ttft_long.mean())),
                ("makespan_secs", Json::from(makespans.mean())),
            ]));
            co_summary.push((n_co, concurrent, ttft_short.mean()));
        }
    }
    let co = |n: usize, concurrent: bool| {
        co_summary
            .iter()
            .find(|&&(c, m, _)| c == n && m == concurrent)
            .map(|&(_, _, t)| t)
            .unwrap_or(f64::NAN)
    };
    println!();
    for &n_co in &[2usize, 4] {
        let ratio = co(n_co, false) / co(n_co, true);
        println!("short-prompt TTFT sequential vs concurrent @ n{n_co}: {ratio:.2}x");
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("coadmit_summary/n{n_co}"))),
            ("co_admitted", Json::from(n_co)),
            ("short_ttft_reduction_concurrent", Json::from(ratio)),
        ]));
    }

    // ------------------------------------------------------------------
    // Admission fairness: decode-stall percentiles while FOUR long
    // prompts admit concurrently (DESIGN.md §5 fairness claim: the
    // per-tick token budget caps prefill work no matter how many prompts
    // share it, so 4-way concurrent admission stalls decoders no worse
    // than 1-way).
    // ------------------------------------------------------------------
    println!(
        "\n{:<34} {:>8} {:>12} {:>12} {:>12}",
        "benchmark", "slots", "max stall", "p99 stall", "last ttft"
    );
    println!("{}", "-".repeat(84));
    let mut fair_summary: Vec<(usize, f64)> = Vec::new();
    for &slots in &[1usize, 4] {
        let mut all_ticks = Summary::new();
        let mut max_stall = Summary::new();
        let mut ttfts = Summary::new();
        for _ in 0..stall_iters {
            let (ticks, ttft) = stall_run(Some(CHUNK), slots, 4, 512, &spec);
            max_stall.add(ticks.iter().cloned().fold(0.0f64, f64::max));
            all_ticks.extend(ticks);
            ttfts.add(ttft);
        }
        println!(
            "{:<34} {:>8} {:>9.2} ms {:>9.2} ms {:>9.2} ms",
            format!("stall4/conc{slots}/p512"),
            slots,
            max_stall.mean() * 1e3,
            all_ticks.percentile(99.0) * 1e3,
            ttfts.mean() * 1e3
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("stall4/conc{slots}/p512"))),
            ("prefill_concurrency", Json::from(slots)),
            ("co_admitted", Json::from(4usize)),
            ("prompt", Json::from(512usize)),
            ("iters", Json::from(stall_iters)),
            // per-tick decode stall seen by the two co-scheduled decoders
            // while all four long prompts admit
            ("decode_stall_max_secs", Json::from(max_stall.mean())),
            ("decode_stall_p50_secs", Json::from(all_ticks.percentile(50.0))),
            ("decode_stall_p99_secs", Json::from(all_ticks.percentile(99.0))),
            ("last_ttft_secs", Json::from(ttfts.mean())),
        ]));
        fair_summary.push((slots, all_ticks.percentile(99.0)));
    }
    if let (Some(&(_, s1)), Some(&(_, s4))) = (fair_summary.first(), fair_summary.last()) {
        let ratio = s4 / s1;
        println!("\np99 decode-stall 4-way concurrent vs sequential: {ratio:.2}x");
        rows.push(Json::obj(vec![
            ("name", Json::str("stall4_summary/p512")),
            ("p99_stall_concurrent_vs_sequential", Json::from(ratio)),
        ]));
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_prefill.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_prefill.json");
    println!("\nwrote results/BENCH_prefill.json");
}
