//! Accuracy-cliff grid (ISSUE 10 tentpole): where each policy's accuracy
//! falls off a cliff as decode length grows past its budget — the Lil
//! very-long-decode workload (8k/16k/32k) over the full policy zoo.
//!
//!     cargo bench --bench accuracy_cliff              # full run
//!     cargo bench --bench accuracy_cliff -- --test    # CI smoke
//!
//! Writes `results/BENCH_accuracy_cliff.json` (uploaded by the CI
//! bench-smoke job; the baseline is provisional, so `bench_compare.py`
//! only warns).  Per (scenario × decode length) a batch of Lil traces is
//! generated ONCE and replayed under every policy × budget cell, plus an
//! unbudgeted dense reference — paired comparison, so `accuracy` and
//! `token_agreement` differences are pure policy effects (see `LilTrace`).
//! The dense reference is pinned *exactly* to the shared answer coins and
//! asserted after the JSON is written.
//!
//! Per non-dense policy a `cliff_budget` summary row reports the smallest
//! budget whose accuracy stays within 0.15 of dense (0 = every budget in
//! the grid is below the cliff) — the number the paper's Figure-6-style
//! grids eyeball.

use raas::config::{EngineConfig, PolicyKind};
use raas::kvcache::policy::make_policy;
use raas::sim::{
    gen_lil_trace, run_lil_trials, LilAggregate, LilScenario, LilTrace, SimParams, LIL_DECODE_LENS,
    LIL_SCENARIOS, MODELS,
};
use raas::util::json::Json;
use raas::util::rng::Rng;

/// Cache budgets (tokens) swept per policy.
const BUDGETS: [usize; 4] = [64, 128, 256, 512];

/// A policy cell is "above the cliff" within this accuracy distance of
/// the dense reference.
const CLIFF_MARGIN: f64 = 0.15;

fn run_cell(kind: PolicyKind, budget: usize, sc: &LilScenario, traces: &[LilTrace],
            target: usize) -> LilAggregate {
    let cfg = EngineConfig {
        policy: kind,
        budget,
        alpha: sc.raas_alpha,
        ..Default::default()
    };
    let policy = make_policy(&cfg);
    let params = SimParams {
        budget_tokens: budget,
        max_decode: target + 4096,
        ..Default::default()
    };
    run_lil_trials(policy.as_ref(), &params, &MODELS[2], sc, traces)
}

fn cell_row(name: String, budget: usize, trials: usize, a: &LilAggregate) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("budget_tokens", Json::from(budget)),
        ("trials", Json::from(trials)),
        ("accuracy", Json::from(a.accuracy)),
        ("token_agreement", Json::from(a.token_agreement)),
        ("mean_decode_len", Json::from(a.mean_decode_len)),
        ("cap_rate", Json::from(a.cap_rate)),
        ("milestone_miss_rate", Json::from(a.milestone_miss_rate)),
        ("phoenix_miss_rate", Json::from(a.phoenix_miss_rate)),
        ("mean_peak_resident", Json::from(a.mean_peak_resident)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let trials = if quick { 1usize } else { 3 };
    let mp = &MODELS[2];

    let mut rows: Vec<Json> = Vec::new();
    // (scenario, len, dense accuracy, coin reference, dense agreement) for
    // the post-write asserts
    let mut dense_checks: Vec<(&str, usize, f64, f64, f64)> = Vec::new();
    println!(
        "{:<44} {:>8} {:>8} {:>8} {:>8} {:>10}",
        "cell", "acc", "agree", "cap", "ms-miss", "peak-tok"
    );
    println!("{}", "-".repeat(92));

    for (si, sc) in LIL_SCENARIOS.iter().enumerate() {
        for &target in &LIL_DECODE_LENS {
            // one trace batch per grid point, shared by every cell below
            let mut rng = Rng::new(0x11f0_0000 + si as u64 * 65_536 + target as u64);
            let traces: Vec<LilTrace> =
                (0..trials).map(|_| gen_lil_trace(sc, mp, target, &mut rng)).collect();

            let dense = run_cell(PolicyKind::Dense, 1 << 24, sc, &traces, target);
            let reference = traces.iter().filter(|t| t.answer_u < sc.base_acc).count() as f64
                / trials as f64;
            let stem = format!("accuracy_cliff/{}/{}k", sc.name, target / 1024);
            println!(
                "{:<44} {:>8.2} {:>8.3} {:>8.2} {:>8.2} {:>10.0}",
                format!("{stem}/dense/reference"),
                dense.accuracy, dense.token_agreement, dense.cap_rate,
                dense.milestone_miss_rate, dense.mean_peak_resident
            );
            rows.push(cell_row(format!("{stem}/dense/reference"), 1 << 24, trials, &dense));
            dense_checks.push((sc.name, target, dense.accuracy, reference,
                               dense.token_agreement));

            for kind in PolicyKind::all() {
                if kind == PolicyKind::Dense {
                    continue;
                }
                let mut cliff_budget = 0usize;
                for &budget in &BUDGETS {
                    let a = run_cell(kind, budget, sc, &traces, target);
                    if cliff_budget == 0 && a.accuracy + 1e-12 >= dense.accuracy - CLIFF_MARGIN
                    {
                        cliff_budget = budget;
                    }
                    let name = format!("{stem}/{}/b{budget}", kind.name());
                    println!(
                        "{:<44} {:>8.2} {:>8.3} {:>8.2} {:>8.2} {:>10.0}",
                        name, a.accuracy, a.token_agreement, a.cap_rate,
                        a.milestone_miss_rate, a.mean_peak_resident
                    );
                    rows.push(cell_row(name, budget, trials, &a));
                }
                rows.push(Json::obj(vec![
                    ("name", Json::str(format!("cliff_budget/{}/{}k/{}", sc.name,
                                               target / 1024, kind.name()))),
                    ("cliff_budget_tokens", Json::from(cliff_budget)),
                    ("dense_accuracy", Json::from(dense.accuracy)),
                    ("cliff_margin", Json::from(CLIFF_MARGIN)),
                ]));
            }
        }
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_accuracy_cliff.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_accuracy_cliff.json");
    println!("\nwrote results/BENCH_accuracy_cliff.json");

    // Acceptance criteria (checked after the JSON is written so a failure
    // still leaves the artifact for debugging): the unbudgeted dense
    // replay is EXACTLY the shared answer coins — no misses, no
    // derailments, full token agreement — at every grid point.
    for (name, target, acc, reference, agree) in dense_checks {
        assert!(
            (acc - reference).abs() < 1e-12,
            "{name}/{target}: dense accuracy {acc} must equal the coin count {reference}"
        );
        assert!(
            (agree - 1.0).abs() < 1e-12,
            "{name}/{target}: dense token agreement {agree} must be exactly 1"
        );
    }
}
