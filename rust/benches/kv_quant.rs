//! KV-quantization benchmark (PR 7): the pool-byte win from fp8/int8 page
//! storage, and what that buys under a *byte-matched* cache budget — the
//! fig6/fig7-shaped question "same bytes, more resident tokens: does
//! accuracy recover?".
//!
//!     cargo bench --bench kv_quant              # full run
//!     cargo bench --bench kv_quant -- --test    # CI smoke
//!
//! Writes `results/BENCH_kv_quant.json` (uploaded by the CI bench-smoke job
//! and gated by `scripts/bench_compare.py`).  One row per policy x dtype
//! cell.  Every cell gets the SAME pool-byte budget: the f32 cell holds
//! [`F32_BUDGET_TOKENS`] tokens, and the quantized cells hold however many
//! tokens fit in the same number of bytes (~4x as many at 1 byte/elem).
//! Per cell we run a fixed problem set through `Engine::generate` and
//! report:
//!
//!  * `bytes_per_page` / `token_budget` — the compression itself (the PR
//!    acceptance criterion, asserted below after the JSON is written:
//!    int8 pages are >= 2x smaller than f32 pages, so the matched token
//!    budget is >= 2x larger);
//!  * `tokens_per_sec` — decode throughput including the dequant cost;
//!  * `answer_accuracy` and `token_agreement` vs an unbudgeted dense-f32
//!    reference, plus `accuracy_delta_vs_f32` against the same policy's
//!    f32 cell (quantization error vs capacity gain, netted out).

use std::time::Instant;

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::{Engine, GenOptions};
use raas::kvcache::KvDtype;
use raas::util::json::Json;
use raas::util::rng::Rng;
use raas::workload::Problem;

/// Token budget of the f32 baseline cell; every other dtype's budget is
/// derived from the byte budget these tokens occupy at 4 bytes/elem.
const F32_BUDGET_TOKENS: usize = 128;

/// Reasoning steps per sampled problem (fixed so prompt/decode lengths —
/// and therefore cache pressure — are comparable across cells).
const STEPS: usize = 8;

fn mk_engine(policy: PolicyKind, dtype: KvDtype, budget: usize) -> Engine {
    let cfg = EngineConfig { policy, budget, kv_dtype: dtype, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512, 2048]).expect("sim engine")
}

/// Positionwise agreement between a cell's token stream and the reference
/// stream: matching positions over the longer length (1.0 == identical).
fn agreement(got: &[u32], want: &[u32]) -> f64 {
    let long = got.len().max(want.len());
    if long == 0 {
        return 1.0;
    }
    let same = got.iter().zip(want).filter(|(a, b)| a == b).count();
    same as f64 / long as f64
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let n_problems = if quick { 3usize } else { 16 };

    // Fixed problem set, shared by every cell (and the reference).
    let mut probe = mk_engine(PolicyKind::Dense, KvDtype::F32, 1 << 20);
    let spec = probe.meta.corpus.clone();
    let page = probe.meta.page_size;
    let kv_dim = probe.meta.model.n_kv_heads * probe.meta.model.head_dim;
    let opts = GenOptions { max_new: spec.max_decode_tokens(STEPS), ..Default::default() };
    let mut rng = Rng::new(7);
    let problems: Vec<(Vec<u32>, u8)> = (0..n_problems)
        .map(|_| {
            let p = Problem::sample(&mut rng, &spec, Some(STEPS));
            (p.encode_prompt(&spec), p.answer())
        })
        .collect();

    // Unbudgeted dense-f32 reference: the accuracy topline every cell's
    // token stream is compared against.  `probe` IS that engine (its huge
    // budget never evicts and dense selects every resident page anyway).
    let reference: Vec<Vec<u32>> = problems
        .iter()
        .map(|(prompt, _)| probe.generate(prompt, &opts).expect("reference generate").tokens)
        .collect();

    // Byte budget every dtype is matched to: the bytes the f32 cell's
    // token budget occupies.
    let f32_pages = F32_BUDGET_TOKENS / page;
    let byte_budget = f32_pages * (2 * page * kv_dim * 4);

    let mut rows: Vec<Json> = Vec::new();
    println!(
        "{:<24} {:>8} {:>8} {:>10} {:>10} {:>8} {:>8}",
        "cell", "B/page", "tokens", "tok/s", "agree", "acc", "d(f32)"
    );
    println!("{}", "-".repeat(84));

    // (policy, dtype, bytes_per_page, token_budget, accuracy) per cell,
    // for the post-write acceptance asserts.
    let mut cells: Vec<(PolicyKind, KvDtype, usize, usize, f64, f64)> = Vec::new();
    for policy in PolicyKind::all() {
        let mut f32_accuracy = 0.0f64;
        for dtype in KvDtype::all() {
            let bytes_per_page = 2 * page * kv_dim * dtype.bytes_per_elem()
                + dtype.page_param_bytes();
            let token_budget = (byte_budget / bytes_per_page).max(1) * page;
            let mut e = mk_engine(policy, dtype, token_budget);
            assert_eq!(
                e.pool().bytes_per_page(),
                bytes_per_page,
                "pool byte accounting must match the budget arithmetic"
            );
            let mut correct = 0usize;
            let mut agree_sum = 0.0f64;
            let mut tokens = 0usize;
            let mut secs = 0.0f64;
            for (i, (prompt, answer)) in problems.iter().enumerate() {
                let t0 = Instant::now();
                let out = e.generate(prompt, &opts).expect("cell generate");
                secs += t0.elapsed().as_secs_f64();
                tokens += out.tokens.len();
                if e.tokenizer.parse_answer(&out.tokens) == Some(*answer) {
                    correct += 1;
                }
                agree_sum += agreement(&out.tokens, &reference[i]);
            }
            let accuracy = correct as f64 / n_problems as f64;
            let agree = agree_sum / n_problems as f64;
            let tps = tokens as f64 / secs.max(1e-12);
            if dtype == KvDtype::F32 {
                f32_accuracy = accuracy;
            }
            let delta = accuracy - f32_accuracy;
            println!(
                "{:<24} {:>8} {:>8} {:>10.0} {:>8.3} {:>8.2} {:>+8.2}",
                format!("kv_quant/{}/{}", policy.name(), dtype.name()),
                bytes_per_page,
                token_budget,
                tps,
                agree,
                accuracy,
                delta
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(format!("kv_quant/{}/{}", policy.name(), dtype.name()))),
                ("policy", Json::str(policy.name())),
                ("kv_dtype", Json::str(dtype.name())),
                ("bytes_per_page", Json::from(bytes_per_page)),
                ("byte_budget", Json::from(byte_budget)),
                ("token_budget", Json::from(token_budget)),
                ("problems", Json::from(n_problems)),
                ("tokens_per_sec", Json::from(tps)),
                ("token_agreement", Json::from(agree)),
                ("answer_accuracy", Json::from(accuracy)),
                ("accuracy_delta_vs_f32", Json::from(delta)),
            ]));
            cells.push((policy, dtype, bytes_per_page, token_budget, accuracy, agree));
        }
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_kv_quant.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_kv_quant.json");
    println!("\nwrote results/BENCH_kv_quant.json");

    // Acceptance criteria (checked after the JSON is written so a failure
    // still leaves the artifact for debugging).
    let f32_page_bytes = 2 * page * kv_dim * 4;
    for &(policy, dtype, bytes_per_page, token_budget, _, agree) in &cells {
        if dtype.is_quantized() {
            // >= 2x pool-byte reduction per page, and therefore >= 2x the
            // resident tokens under the matched byte budget.
            assert!(
                f32_page_bytes >= 2 * bytes_per_page,
                "{dtype}: quantized pages must be >= 2x smaller than f32 \
                 ({f32_page_bytes} vs {bytes_per_page} bytes)"
            );
            assert!(
                token_budget >= 2 * F32_BUDGET_TOKENS,
                "{dtype}: matched byte budget must hold >= 2x the f32 tokens \
                 ({token_budget} vs {F32_BUDGET_TOKENS})"
            );
        } else {
            assert_eq!(token_budget, F32_BUDGET_TOKENS);
        }
        if policy == PolicyKind::Dense && dtype == KvDtype::F32 {
            // dense ignores the budget and f32 is the bit-exact reference
            // path, so this cell must reproduce the topline stream exactly
            assert_eq!(agree, 1.0, "dense/f32 must match the reference bitwise");
        }
    }
}
