//! Prefix-cache benchmark (PR 6): the TTFT win from attaching cached
//! prefix pages instead of re-running prefill over them, and the pool-byte
//! win from refcounted page sharing.
//!
//!     cargo bench --bench prefix_cache              # full run
//!     cargo bench --bench prefix_cache -- --test    # CI smoke
//!
//! Writes `results/BENCH_prefix_cache.json` (uploaded by the CI bench-smoke
//! job and gated by `scripts/bench_compare.py`).  Expected shape:
//!
//!  * warm-prefix TTFT strictly below cold TTFT at prompt >= 512 with a
//!    shared 256-token prefix (the PR acceptance criterion — asserted
//!    below after the JSON is written): the warm prompt attaches the
//!    shared prefix's pages from the pool-level index and computes only
//!    its own continuation;
//!  * pool bytes per active sequence collapse under forked sharing: N
//!    forks of one prefilled sequence hold one physical copy of the
//!    prompt's pages, vs N copies for N independent prefills.

use std::time::Instant;

use raas::config::{ArtifactMeta, CorpusSpec, EngineConfig, PolicyKind};
use raas::engine::Engine;
use raas::util::json::Json;
use raas::util::stats::Summary;

/// Tokens shared between the seeding prompt and the measured prompt.
const PREFIX: usize = 256;

fn mk_engine(prefix_cache: bool) -> Engine {
    let cfg = EngineConfig { policy: PolicyKind::Raas, prefix_cache, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

/// A `len`-token prompt whose first [`PREFIX`] tokens are a fixed shared
/// header and whose continuation varies by `variant` (so a warm lookup
/// hits exactly the shared prefix, never the continuation).
fn prompt_of(len: usize, variant: usize, spec: &CorpusSpec) -> Vec<u32> {
    (0..len)
        .map(|i| {
            if i < PREFIX {
                spec.dig0 + (i % 10) as u32
            } else {
                spec.dig0 + ((i * 7 + 3 * variant + 1) % 10) as u32
            }
        })
        .collect()
}

/// One timed whole-prompt prefill (TTFT without queueing).
fn prefill_once(e: &mut Engine, prompt: &[u32]) -> (f64, usize) {
    let mut seq = e.new_seq();
    let t0 = Instant::now();
    e.prefill_seq(&mut seq, prompt).expect("prefill");
    let secs = t0.elapsed().as_secs_f64();
    let cached = seq.prefix_cached_tokens;
    e.release_seq(&mut seq);
    (secs, cached)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let (warmup, iters) = if quick { (1usize, 3usize) } else { (3, 15) };
    let meta = ArtifactMeta::sim_default();
    let spec = meta.corpus.clone();
    let page = meta.page_size;

    let mut rows: Vec<Json> = Vec::new();
    println!(
        "{:<30} {:>8} {:>12} {:>12} {:>10}",
        "benchmark", "prompt", "cold ttft", "warm ttft", "speedup"
    );
    println!("{}", "-".repeat(78));

    // ------------------------------------------------------------------
    // Cold vs warm-prefix TTFT.  Per iteration: a fresh engine prefills
    // the seeding prompt (cold — the index is empty; this also publishes
    // the shared prefix), then the measured prompt (warm — the 256-token
    // shared prefix attaches, only the continuation computes).
    // ------------------------------------------------------------------
    let mut ttft_pairs: Vec<(usize, f64, f64)> = Vec::new();
    for &plen in &[512usize, 1024] {
        let seeding = prompt_of(plen, 0, &spec);
        let measured = prompt_of(plen, 1, &spec);
        let mut cold = Summary::new();
        let mut warm = Summary::new();
        let mut cached_tokens = 0usize;
        for it in 0..warmup + iters {
            let mut e = mk_engine(true);
            let (cold_secs, seed_cached) = prefill_once(&mut e, &seeding);
            assert_eq!(seed_cached, 0, "seeding prefill must run cold");
            let (warm_secs, warm_cached) = prefill_once(&mut e, &measured);
            assert_eq!(warm_cached, PREFIX, "warm prefill must attach the shared prefix");
            cached_tokens = warm_cached;
            if it >= warmup {
                cold.add(cold_secs);
                warm.add(warm_secs);
            }
        }
        let speedup = cold.mean() / warm.mean();
        println!(
            "{:<30} {:>8} {:>9.2} ms {:>9.2} ms {:>9.2}x",
            format!("prefix_ttft/p{plen}"),
            plen,
            cold.mean() * 1e3,
            warm.mean() * 1e3,
            speedup
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("prefix_ttft/p{plen}"))),
            ("prompt", Json::from(plen)),
            ("shared_prefix_tokens", Json::from(PREFIX)),
            ("cached_tokens", Json::from(cached_tokens)),
            ("iters", Json::from(cold.count())),
            ("cold_ttft_mean_secs", Json::from(cold.mean())),
            ("cold_ttft_p50_secs", Json::from(cold.percentile(50.0))),
            ("warm_ttft_mean_secs", Json::from(warm.mean())),
            ("warm_ttft_p50_secs", Json::from(warm.percentile(50.0))),
            ("warm_speedup", Json::from(speedup)),
        ]));
        ttft_pairs.push((plen, cold.mean(), warm.mean()));
    }

    // ------------------------------------------------------------------
    // Pool bytes per active sequence: N forks of one prefilled sequence
    // (one physical copy, refcounted) vs N independent prefills (N
    // copies).  Static residency — no decode, so no COW divergence.
    // ------------------------------------------------------------------
    println!(
        "\n{:<30} {:>8} {:>14} {:>14} {:>8}",
        "benchmark", "seqs", "shared B/seq", "indep B/seq", "ratio"
    );
    println!("{}", "-".repeat(80));
    let plen = 512usize;
    let n_seqs = 8usize;
    let prompt = prompt_of(plen, 0, &spec);
    let bytes_per_seq = |pool: &raas::kvcache::KvPool, n: usize| {
        pool.allocated_pages() * pool.bytes_per_page() / n
    };
    let shared = {
        let mut e = mk_engine(false);
        let mut parent = e.new_seq();
        e.prefill_seq(&mut parent, &prompt).expect("prefill");
        let mut forks: Vec<_> = (0..n_seqs - 1).map(|_| e.fork_seq(&parent)).collect();
        let per_seq = bytes_per_seq(e.pool(), n_seqs);
        for f in forks.iter_mut() {
            e.release_seq(f);
        }
        e.release_seq(&mut parent);
        assert_eq!(e.pool().allocated_pages(), 0, "pool must drain");
        per_seq
    };
    let independent = {
        let mut e = mk_engine(false);
        let mut seqs: Vec<_> = (0..n_seqs)
            .map(|_| {
                let mut s = e.new_seq();
                e.prefill_seq(&mut s, &prompt).expect("prefill");
                s
            })
            .collect();
        let per_seq = bytes_per_seq(e.pool(), n_seqs);
        for s in seqs.iter_mut() {
            e.release_seq(s);
        }
        per_seq
    };
    let ratio = independent as f64 / shared as f64;
    println!(
        "{:<30} {:>8} {:>14} {:>14} {:>7.2}x",
        format!("pool_bytes/forked/p{plen}"),
        n_seqs,
        shared,
        independent,
        ratio
    );
    rows.push(Json::obj(vec![
        ("name", Json::str(format!("pool_bytes/forked/p{plen}"))),
        ("prompt", Json::from(plen)),
        ("sequences", Json::from(n_seqs)),
        ("pool_bytes_per_seq_shared", Json::from(shared)),
        ("pool_bytes_per_seq_independent", Json::from(independent)),
        ("sharing_ratio", Json::from(ratio)),
    ]));

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_prefix_cache.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_prefix_cache.json");
    println!("\nwrote results/BENCH_prefix_cache.json");

    // Acceptance criterion (checked after the JSON is written so a failure
    // still leaves the artifact for debugging): at prompt >= 512 with a
    // 256-token shared prefix, warm TTFT must beat cold TTFT.
    for (plen, cold, warm) in ttft_pairs {
        assert!(warm < cold,
                "warm-prefix TTFT ({:.3} ms) must beat cold TTFT ({:.3} ms) at p{plen}",
                warm * 1e3, cold * 1e3);
    }
    assert!(shared < independent, "forked sequences must share pool bytes");
}
