//! End-to-end decode throughput: the pre-batching serving loop (one
//! `Engine::decode_step` per sequence per iteration) vs the batched path
//! (`Engine::decode_batch`) at batch sizes 1/4/8 on the sim backend, plus
//! the zero-copy paged attention route vs the classic gather route at
//! growing context lengths.
//!
//!     cargo bench --bench decode_throughput              # full run
//!     cargo bench --bench decode_throughput -- --test    # CI smoke (--quick works too)
//!
//! Writes `results/BENCH_decode_throughput.json` and
//! `results/BENCH_paged_attention.json` (both uploaded by CI next to the
//! policy-overhead artifact).  Acceptance (ISSUE 2): batched batch-8
//! total tokens/sec must be >= 2x the sequential batch-1 per-sequence
//! throughput.  Acceptance (ISSUE 3): the paged route must be at or above
//! the gathered route's tokens/sec at every measured context length, with
//! the gap widening as resident tokens grow — the gather route pays an
//! O(resident) memcpy plus capacity-padding zero-fill per layer per step
//! that the paged route deletes outright.
//!
//! The batching workload co-schedules same-length, distinct-content
//! prompts (the continuous batcher admits prefill-first, so co-resident
//! sequences typically sit at aligned positions): content differs per
//! sequence, so value aggregation and lm-head stay per-item work;
//! positions align, so the position-pure score/softmax work is shared.
//! The paged-vs-gathered workload decodes a single sequence under the
//! Dense policy (everything resident and selected — `force_len`-style
//! fixed decode length), so the per-layer copy cost scales with context
//! and dominates the step.

use std::time::Instant;

use raas::config::{ArtifactMeta, CorpusSpec, EngineConfig, PolicyKind};
use raas::engine::{BatchEntry, Engine};
use raas::kvcache::SeqCache;
use raas::runtime::SimBackend;
use raas::util::json::Json;
use raas::util::rng::Rng;
use raas::util::stats::Summary;
use raas::workload::Problem;

#[path = "../tests/support/gathered_sim.rs"]
mod gathered_sim;
use gathered_sim::GatheredSim;

const BUDGET: usize = 192;

fn engine() -> Engine {
    let cfg = EngineConfig { policy: PolicyKind::Raas, budget: BUDGET, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

/// `b` same-length prompts with distinct digit content: co-positioned
/// (maximal legitimate sharing) but different hidden states per sequence.
fn make_prompts(b: usize, spec: &CorpusSpec, rng: &mut Rng) -> Vec<Vec<u32>> {
    let base = Problem::sample(rng, spec, Some(8)).encode_prompt(spec);
    (0..b)
        .map(|i| {
            let mut p = base.clone();
            let mut k = 0u32;
            for t in p.iter_mut() {
                if *t >= spec.dig0 && *t < spec.dig0 + 10 {
                    *t = spec.dig0 + (*t - spec.dig0 + i as u32 + k) % 10;
                    k += 1;
                }
            }
            p
        })
        .collect()
}

fn prefill_all(e: &mut Engine, prompts: &[Vec<u32>]) -> (Vec<SeqCache>, Vec<u32>) {
    let mut seqs = Vec::with_capacity(prompts.len());
    let mut toks = Vec::with_capacity(prompts.len());
    for p in prompts {
        let mut s = e.new_seq();
        toks.push(e.prefill_seq(&mut s, p).expect("prefill"));
        seqs.push(s);
    }
    (seqs, toks)
}

/// One timed run: prefill outside the timer, `steps` decode iterations
/// inside.  Returns decode wall seconds.
fn run_once(e: &mut Engine, prompts: &[Vec<u32>], steps: usize, batched: bool) -> f64 {
    let (mut seqs, mut toks) = prefill_all(e, prompts);
    let t0 = Instant::now();
    if batched {
        for step in 1..=steps {
            let mut entries: Vec<BatchEntry<'_>> = seqs
                .iter_mut()
                .enumerate()
                .map(|(i, seq)| BatchEntry::new(seq, toks[i], step as u64))
                .collect();
            let results = e.decode_batch(&mut entries);
            drop(entries);
            for (tok, r) in toks.iter_mut().zip(results) {
                *tok = r.expect("batched decode");
            }
        }
    } else {
        for step in 1..=steps {
            for (i, seq) in seqs.iter_mut().enumerate() {
                toks[i] = e.decode_step(seq, toks[i], step as u64, None).expect("decode");
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    for mut s in seqs {
        e.release_seq(&mut s);
    }
    secs
}

/// Engine for the paged-vs-gathered comparison: Dense policy (everything
/// resident and attended, so copy cost scales with context).
fn ctx_engine(ctx: usize, paged: bool) -> Engine {
    let cfg = EngineConfig { policy: PolicyKind::Dense, budget: ctx * 2, ..Default::default() };
    if paged {
        Engine::new(cfg).expect("sim engine")
    } else {
        let meta = ArtifactMeta::sim_default();
        let model = Box::new(GatheredSim(SimBackend::new(&meta, cfg.seed)));
        Engine::with_backend(cfg, meta, model).expect("gathered engine")
    }
}

/// A `ctx`-token prompt of plain digit/index tokens (content is irrelevant
/// here: only the resident-set size matters).
fn ctx_prompt(ctx: usize, spec: &CorpusSpec) -> Vec<u32> {
    (0..ctx).map(|i| spec.dig0 + (i % 10) as u32).collect()
}

/// One timed run at a fixed context: prefill `ctx` tokens outside the
/// timer, then `steps` batched decode iterations (batch 1) inside.
fn run_ctx_once(e: &mut Engine, prompt: &[u32], steps: usize) -> f64 {
    let mut seq = e.new_seq();
    let mut tok = e.prefill_seq(&mut seq, prompt).expect("prefill");
    let t0 = Instant::now();
    for step in 1..=steps {
        let mut entries = vec![BatchEntry::new(&mut seq, tok, step as u64)];
        let results = e.decode_batch(&mut entries);
        drop(entries);
        tok = results.into_iter().next().unwrap().expect("decode");
    }
    let secs = t0.elapsed().as_secs_f64();
    e.release_seq(&mut seq);
    secs
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let (steps, iters, warmup) = if quick { (48, 4, 1) } else { (160, 12, 2) };
    let mut rng = Rng::new(7);

    println!(
        "{:<26} {:>6} {:>8} {:>12} {:>14}",
        "benchmark", "batch", "steps", "mean", "tokens/sec"
    );
    println!("{}", "-".repeat(70));

    let mut rows: Vec<Json> = Vec::new();
    let mut rates: Vec<(String, usize, f64)> = Vec::new();
    for &b in &[1usize, 4, 8] {
        // both modes measure the exact same prompts (before/after fairness)
        let spec = ArtifactMeta::sim_default().corpus;
        let prompts = make_prompts(b, &spec, &mut rng);
        for &batched in &[false, true] {
            let mode = if batched { "batched" } else { "sequential" };
            // fresh engine per series: memo warm-up happens in the warmup
            // iterations, so both modes measure steady-state throughput
            let mut e = engine();
            for _ in 0..warmup {
                run_once(&mut e, &prompts, steps, batched);
            }
            let mut s = Summary::new();
            for _ in 0..iters {
                s.add(run_once(&mut e, &prompts, steps, batched));
            }
            let tokens = (b * steps) as f64;
            let toks_per_sec = tokens / s.mean();
            println!(
                "{:<26} {:>6} {:>8} {:>9.2} ms {:>14.0}",
                format!("decode/{mode}/b{b}"),
                b,
                steps,
                s.mean() * 1e3,
                toks_per_sec
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(format!("decode/{mode}/b{b}"))),
                ("mode", Json::str(mode)),
                ("batch", Json::from(b)),
                ("steps", Json::from(steps)),
                ("iters", Json::from(s.count())),
                ("mean_secs", Json::from(s.mean())),
                ("p50_secs", Json::from(s.percentile(50.0))),
                ("min_secs", Json::from(s.min())),
                ("tokens_per_sec", Json::from(toks_per_sec)),
            ]));
            rates.push((mode.to_string(), b, toks_per_sec));
        }
    }

    let rate = |mode: &str, b: usize| {
        rates
            .iter()
            .find(|(m, bb, _)| m == mode && *bb == b)
            .map(|&(_, _, r)| r)
            .unwrap_or(f64::NAN)
    };
    let speedup = rate("batched", 8) / rate("sequential", 1);
    println!(
        "\nbatched-b8 vs sequential-b1 per-sequence throughput: {speedup:.2}x (target >= 2.0)"
    );
    let b4 = rate("batched", 4) / rate("sequential", 1);
    let b1 = rate("batched", 1) / rate("sequential", 1);
    rows.push(Json::obj(vec![
        ("name", Json::str("summary")),
        ("speedup_batched_b8_vs_sequential_b1", Json::from(speedup)),
        ("speedup_batched_b4_vs_sequential_b1", Json::from(b4)),
        ("speedup_batched_b1_vs_sequential_b1", Json::from(b1)),
        ("target", Json::from(2.0)),
    ]));

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_decode_throughput.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_decode_throughput.json");
    println!("wrote results/BENCH_decode_throughput.json");

    // ------------------------------------------------------------------
    // Paged vs gathered attention route at growing context lengths.
    // ------------------------------------------------------------------
    let ctxs: &[usize] = if quick { &[256, 1024] } else { &[256, 1024, 4096] };
    let (ctx_steps, ctx_iters, ctx_warmup) = if quick { (24, 3, 1) } else { (96, 8, 2) };
    println!(
        "\n{:<30} {:>8} {:>8} {:>12} {:>14}",
        "benchmark", "context", "steps", "mean", "tokens/sec"
    );
    println!("{}", "-".repeat(76));
    let mut paged_rows: Vec<Json> = Vec::new();
    let mut ctx_rates: Vec<(usize, bool, f64)> = Vec::new();
    let spec = ArtifactMeta::sim_default().corpus;
    for &ctx in ctxs {
        let prompt = ctx_prompt(ctx, &spec);
        for &paged in &[false, true] {
            let mode = if paged { "paged" } else { "gathered" };
            let mut e = ctx_engine(ctx, paged);
            for _ in 0..ctx_warmup {
                run_ctx_once(&mut e, &prompt, ctx_steps);
            }
            let mut s = Summary::new();
            for _ in 0..ctx_iters {
                s.add(run_ctx_once(&mut e, &prompt, ctx_steps));
            }
            let toks_per_sec = ctx_steps as f64 / s.mean();
            println!(
                "{:<30} {:>8} {:>8} {:>9.2} ms {:>14.0}",
                format!("decode/{mode}/ctx{ctx}"),
                ctx,
                ctx_steps,
                s.mean() * 1e3,
                toks_per_sec
            );
            paged_rows.push(Json::obj(vec![
                ("name", Json::str(format!("decode/{mode}/ctx{ctx}"))),
                ("mode", Json::str(mode)),
                ("context", Json::from(ctx)),
                ("resident_tokens", Json::from(ctx + ctx_steps)),
                ("steps", Json::from(ctx_steps)),
                ("iters", Json::from(s.count())),
                ("mean_secs", Json::from(s.mean())),
                ("p50_secs", Json::from(s.percentile(50.0))),
                ("min_secs", Json::from(s.min())),
                ("tokens_per_sec", Json::from(toks_per_sec)),
            ]));
            ctx_rates.push((ctx, paged, toks_per_sec));
        }
    }
    let ctx_rate = |ctx: usize, paged: bool| {
        ctx_rates
            .iter()
            .find(|&&(c, p, _)| c == ctx && p == paged)
            .map(|&(_, _, r)| r)
            .unwrap_or(f64::NAN)
    };
    println!();
    for &ctx in ctxs {
        let speedup = ctx_rate(ctx, true) / ctx_rate(ctx, false);
        println!("paged vs gathered @ ctx {ctx}: {speedup:.2}x (target >= 1.0, widening)");
        paged_rows.push(Json::obj(vec![
            ("name", Json::str(format!("summary/ctx{ctx}"))),
            ("context", Json::from(ctx)),
            ("speedup_paged_vs_gathered", Json::from(speedup)),
            ("target", Json::from(1.0)),
        ]));
    }
    std::fs::write("results/BENCH_paged_attention.json", Json::Arr(paged_rows).to_string())
        .expect("write results/BENCH_paged_attention.json");
    println!("wrote results/BENCH_paged_attention.json");
}
