//! End-to-end bench behind paper Figure 7 / Table rows: per-token decode
//! latency and resident memory for Dense / Quest / RaaS at increasing
//! context lengths.  Runs on whichever backend the default `EngineConfig`
//! selects — the hermetic `sim` surrogate out of the box; build with
//! `--features backend-xla` (plus `make artifacts`) and flip the backend to
//! measure the PJRT path.
//!
//!     cargo bench --bench fig7_latency_memory

use raas::bench::{fmt_ns, Bencher, BenchConfig};
use raas::config::{EngineConfig, PolicyKind};
use raas::engine::{Engine, GenOptions};
use raas::util::rng::Rng;
use raas::workload::Problem;

fn main() {
    let mut b = Bencher::new(BenchConfig {
        warmup_iters: 0,
        iters: 2,
        max_time: std::time::Duration::from_secs(120),
    });
    Bencher::print_header();

    for kind in [PolicyKind::Dense, PolicyKind::Quest, PolicyKind::Raas] {
        for &decode_len in &[128usize, 512] {
            let cfg = EngineConfig { policy: kind, budget: 512, ..Default::default() };
            let mut engine = match Engine::new_with_capacities(cfg, &[64, 256, 512, 1024, 2048]) {
                Ok(e) => e,
                Err(e) => {
                    println!("SKIP ({kind:?}): {e:#}");
                    continue;
                }
            };
            let spec = engine.meta.corpus.clone();
            let mut rng = Rng::new(7);
            let mut prompt = Vec::new();
            while prompt.len() < 128 {
                prompt.extend(Problem::sample(&mut rng, &spec, None).encode_prompt(&spec));
            }
            prompt.truncate(128);
            let mut peak = 0usize;
            let r = b.bench(&format!("{}/decode{decode_len}", kind.name()), || {
                let out = engine
                    .generate(
                        &prompt,
                        &GenOptions {
                            max_new: decode_len,
                            force_len: Some(decode_len),
                            ..Default::default()
                        },
                    )
                    .expect("generate");
                peak = peak.max(out.peak_resident_bytes);
                out.decode_secs
            });
            println!(
                "    -> {} per token, peak resident {} bytes",
                fmt_ns(r.mean_ns / decode_len as f64),
                peak
            );
        }
    }
    std::fs::create_dir_all("results").ok();
    b.dump_json("results/bench_fig7.json").ok();
    println!("\nwrote results/bench_fig7.json (full curves: `raas fig7`)");
}
