//! Microbenchmark: per-step policy overhead (observe + select + evict
//! bookkeeping) as a function of resident page count.  This is the L3 cost
//! the paper claims is negligible (Appendix B) — EXPERIMENTS.md §Perf
//! records it against the PJRT step time.
//!
//!     cargo bench --bench policy_overhead              # full run
//!     cargo bench --bench policy_overhead -- --test    # CI smoke (--quick works too)
//!
//! Writes `results/BENCH_policy_overhead.json`, the artifact the CI bench
//! job uploads to seed the perf trajectory.

use raas::bench::{Bencher, BenchConfig};
use raas::config::{EngineConfig, PolicyKind};
use raas::kvcache::page::{page_probs, PageMeta, RepBounds};
use raas::kvcache::policy::make_policy;
use raas::util::rng::Rng;

fn mk_table(n_pages: usize, rng: &mut Rng) -> (Vec<PageMeta>, Vec<f32>) {
    let mut table = Vec::new();
    let mut scores = Vec::new();
    for i in 0..n_pages {
        let mut m = PageMeta::new(i as u32, i * 16, i < 4, 0);
        m.len = 16;
        table.push(m);
        scores.push(rng.f64() as f32 * 4.0 - 2.0);
    }
    (table, scores)
}

fn main() {
    // `--test` / `--quick`: a fast smoke pass (CI); full fidelity otherwise.
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let mut rng = Rng::new(42);
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, iters: 10, ..Default::default() }
    } else {
        BenchConfig { warmup_iters: 10, iters: 200, ..Default::default() }
    };
    let mut b = Bencher::new(cfg);
    Bencher::print_header();

    let page_counts: &[usize] = if quick { &[16, 256] } else { &[16, 64, 256, 1024] };
    for &n_pages in page_counts {
        let (mut table, scores) = mk_table(n_pages, &mut rng);
        let mut probs = Vec::new();
        page_probs(&scores, 16, &mut probs);

        for kind in PolicyKind::all() {
            let cfg = EngineConfig { policy: kind, budget: n_pages * 16 / 2, ..Default::default() };
            let policy = make_policy(&cfg);
            // reusable scratch, like the engine's decode paths — the bench
            // measures policy work, not the allocator
            let mut sel: Vec<usize> = Vec::new();
            b.bench(&format!("{}/observe+select+evict/{n_pages}p", kind.name()), || {
                policy.observe(&mut table, &probs, 1);
                policy.select_into(&table, &scores, cfg.budget, 16, &mut sel);
                let ev = policy.evict_candidate(&table);
                (sel.len(), ev)
            });
        }
        // rep scoring itself (the rust-side O(pages) hot loop)
        let rep = RepBounds {
            kmin: vec![-1.0; 64],
            kmax: vec![1.0; 64],
        };
        let q = vec![0.5f32; 128];
        b.bench(&format!("rep_score/{n_pages}p"), || {
            let mut acc = 0.0f32;
            for _ in 0..n_pages {
                acc += rep.score(&q, 8, 4, 16);
            }
            acc
        });
        b.bench(&format!("page_probs/{n_pages}p"), || {
            page_probs(&scores, 16, &mut probs);
            probs.len()
        });
    }

    std::fs::create_dir_all("results").ok();
    b.dump_json("results/BENCH_policy_overhead.json").ok();
    println!("\nwrote results/BENCH_policy_overhead.json");
}
