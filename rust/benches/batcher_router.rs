//! Coordinator microbenchmarks: continuous-batcher tick throughput and
//! router dispatch cost over a mock backend (pure L3 scheduling overhead,
//! independent of PJRT).
//!
//!     cargo bench --bench batcher_router

use std::sync::mpsc::channel;

use anyhow::Result;
use raas::bench::{Bencher, BenchConfig};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend};
use raas::coordinator::request::Request;

struct NullBackend;

impl StepBackend for NullBackend {
    type Seq = u32;
    fn begin(&mut self, prompt: &[u32]) -> Result<(u32, u32)> {
        Ok((prompt.len() as u32, 1))
    }
    fn step(&mut self, seq: &mut u32, _token: u32, _now: u64) -> Result<u32> {
        *seq = seq.wrapping_mul(1664525).wrapping_add(1013904223);
        Ok(1 + (*seq % 40))
    }
    fn finish(&mut self, _seq: u32) {}
    fn is_eos(&self, token: u32) -> bool {
        token == 0
    }
    fn has_capacity(&self, active: usize) -> bool {
        active < 64
    }
}

fn main() {
    let mut b = Bencher::new(BenchConfig { warmup_iters: 3, iters: 50, ..Default::default() });
    Bencher::print_header();

    for &batch in &[1usize, 8, 32] {
        b.bench(&format!("batcher/tick/{batch}seqs"), || {
            let (tx, _rx) = channel();
            let mut batcher =
                Batcher::new(NullBackend, BatcherConfig { max_batch: batch, ..Default::default() });
            for id in 0..batch as u64 {
                batcher.submit(Request::new(id, vec![1, 2, 3], 64, tx.clone()));
            }
            // 64 scheduler iterations over `batch` live sequences
            let mut steps = 0;
            for _ in 0..64 {
                steps += batcher.tick();
            }
            steps
        });
    }

    // queue pressure: deep FIFO drained through 8 slots — admission must
    // stay O(1) per pop (VecDeque; a Vec::remove(0) queue was O(n²) here)
    b.bench("batcher/queue_pressure/1024reqs", || {
        let (tx, _rx) = channel();
        let cfg = BatcherConfig { max_batch: 8, ..Default::default() };
        let mut batcher = Batcher::new(NullBackend, cfg);
        for id in 0..1024u64 {
            batcher.submit(Request::new(id, vec![1], 4, tx.clone()));
        }
        batcher.run_to_completion();
        batcher.completed
    });

    std::fs::create_dir_all("results").ok();
    b.dump_json("results/bench_batcher_router.json").ok();
    println!("\nwrote results/bench_batcher_router.json");
}
