//! Robustness benchmark (ISSUE 8): serving goodput and tail latency under
//! injected fault rates, plus the park→resume overhead of the two
//! preemption modes.
//!
//!     cargo bench --bench robustness              # full run
//!     cargo bench --bench robustness -- --test    # CI smoke
//!
//! Writes `results/BENCH_robustness.json` (uploaded by the CI bench-smoke
//! job).  Two sections:
//!
//!  * `faults/rateNN` — a fixed request mix served through
//!    `Batcher<StepFaultInjector<EngineBackend>>` at overall fault rates
//!    0% / 5% / 20%.  A rate `r` means: each admission faults with
//!    probability `r`, and each decode step / page allocation with `r/20`
//!    (alloc faults force real preemptions mid-run).  Reported: goodput
//!    (completed tokens per wall-second — failures produce nothing),
//!    p50/p99 job-completion time over completed requests, and the
//!    done/failed/shed/preempted tallies.
//!  * `preempt/{mode}/pN` — the cost of one park→resume cycle at the
//!    `EngineBackend` layer: `restore` pays two page-copy passes
//!    (swap-out + swap-in), `recompute` pays a free park and a
//!    prompt+history replay on resume.

use std::sync::mpsc::channel;
use std::time::Instant;

use raas::config::{EngineConfig, PolicyKind, PreemptMode};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend};
use raas::coordinator::request::{Outcome, Request, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::Engine;
use raas::runtime::{FaultOp, FaultSchedule, StepFaultInjector};
use raas::util::json::Json;
use raas::util::stats::Summary;

fn mk_engine() -> Engine {
    let cfg = EngineConfig { policy: PolicyKind::Raas, budget: 96, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

struct RunStats {
    done: usize,
    failed: usize,
    shed: usize,
    preemptions: u64,
    tokens: usize,
    wall_secs: f64,
    jcts: Vec<f64>,
}

/// Serve `n_reqs` fixed requests under an overall fault rate; returns the
/// outcome tally, completed-token count and per-completion JCTs.
fn faulted_run(rate: f64, n_reqs: u64, max_new: usize, seed: u64) -> RunStats {
    let mut schedule = FaultSchedule::new(seed);
    if rate > 0.0 {
        schedule = schedule
            .rate(FaultOp::Begin, rate)
            .rate(FaultOp::Step, rate / 20.0)
            .rate(FaultOp::Alloc, rate / 20.0);
    }
    let backend =
        StepFaultInjector::new(EngineBackend::new(mk_engine()).with_page_estimate(8), schedule);
    let mut b = Batcher::new(backend, BatcherConfig { max_batch: 4, ..Default::default() });
    let (tx, rx) = channel::<Response>();
    let t0 = Instant::now();
    for id in 0..n_reqs {
        let prompt: Vec<u32> = (0..32).map(|i| 1 + ((i + id as usize) % 40) as u32).collect();
        b.submit(Request::new(id, prompt, max_new, tx.clone()));
    }
    b.run_to_completion();
    let wall_secs = t0.elapsed().as_secs_f64();
    drop(tx);
    let mut s = RunStats {
        done: 0,
        failed: 0,
        shed: 0,
        preemptions: b.preemptions,
        tokens: 0,
        wall_secs,
        jcts: Vec::new(),
    };
    for r in rx.iter() {
        match r.outcome {
            Outcome::Done => {
                s.done += 1;
                s.tokens += r.tokens.len();
                s.jcts.push(r.jct_secs);
            }
            Outcome::Failed => s.failed += 1,
            Outcome::Shed => s.shed += 1,
        }
    }
    assert_eq!(s.done + s.failed + s.shed, n_reqs as usize, "lost requests under faults");
    assert_eq!(
        b.backend.inner.engine.pool().allocated_pages(),
        0,
        "faulted run leaked pool pages"
    );
    s
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let mut rows: Vec<Json> = Vec::new();

    // ------------------------------------------------------------------
    // Goodput + tail latency vs fault rate.
    // ------------------------------------------------------------------
    let n_reqs: u64 = if quick { 12 } else { 48 };
    let reps: usize = if quick { 1 } else { 3 };
    let max_new = 32usize;
    println!(
        "{:<18} {:>6} {:>6} {:>6} {:>8} {:>14} {:>10} {:>10}",
        "benchmark", "done", "fail", "shed", "preempt", "goodput tok/s", "p50 jct", "p99 jct"
    );
    println!("{}", "-".repeat(86));
    let mut goodputs: Vec<(usize, f64)> = Vec::new();
    for &rate in &[0.0f64, 0.05, 0.20] {
        let pct = (rate * 100.0).round() as usize;
        let (mut done, mut failed, mut shed, mut tokens) = (0usize, 0usize, 0usize, 0usize);
        let mut preemptions = 0u64;
        let mut wall = 0.0f64;
        let mut jcts = Summary::new();
        for rep in 0..reps {
            let s = faulted_run(rate, n_reqs, max_new, 11 + rep as u64);
            done += s.done;
            failed += s.failed;
            shed += s.shed;
            tokens += s.tokens;
            preemptions += s.preemptions;
            wall += s.wall_secs;
            jcts.extend(s.jcts);
        }
        let goodput = tokens as f64 / wall;
        let (p50, p99) = if jcts.count() > 0 {
            (jcts.percentile(50.0), jcts.percentile(99.0))
        } else {
            (0.0, 0.0)
        };
        println!(
            "{:<18} {:>6} {:>6} {:>6} {:>8} {:>14.0} {:>7.2} ms {:>7.2} ms",
            format!("faults/rate{pct:02}"),
            done,
            failed,
            shed,
            preemptions,
            goodput,
            p50 * 1e3,
            p99 * 1e3
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(format!("faults/rate{pct:02}"))),
            ("fault_rate", Json::from(rate)),
            ("requests", Json::from(n_reqs as usize * reps)),
            ("max_new", Json::from(max_new)),
            ("done", Json::from(done)),
            ("failed", Json::from(failed)),
            ("shed", Json::from(shed)),
            ("preemptions", Json::from(preemptions as usize)),
            // completed tokens per wall-second: the headline robustness
            // metric — failures and sheds contribute time but no tokens
            ("goodput_tokens_per_sec", Json::from(goodput)),
            ("jct_p50_secs", Json::from(p50)),
            ("jct_p99_secs", Json::from(p99)),
        ]));
        goodputs.push((pct, goodput));
    }
    if let (Some(&(_, g0)), Some(&(_, g20))) = (goodputs.first(), goodputs.last()) {
        let retained = g20 / g0;
        println!("\ngoodput retained at 20% faults: {:.0}%", retained * 100.0);
        rows.push(Json::obj(vec![
            ("name", Json::str("faults_summary")),
            ("goodput_retained_at_rate20", Json::from(retained)),
        ]));
    }

    // ------------------------------------------------------------------
    // Park→resume cycle cost, restore vs recompute.
    // ------------------------------------------------------------------
    let iters: usize = if quick { 3 } else { 20 };
    println!(
        "\n{:<22} {:>8} {:>12} {:>12} {:>12} {:>16}",
        "benchmark", "prompt", "park", "resume", "cycle", "moved/replayed"
    );
    println!("{}", "-".repeat(88));
    for &plen in &[128usize, 512] {
        let prompt: Vec<u32> = (0..plen).map(|i| 1 + (i % 40) as u32).collect();
        for mode in [PreemptMode::Restore, PreemptMode::Recompute] {
            let mut be = EngineBackend::new(mk_engine());
            let (mut seq, mut tok) = be.begin(&prompt).expect("begin");
            let mut produced = Vec::new();
            for step in 1..=4u64 {
                produced.push(tok);
                tok = be.step(&mut seq, tok, step).expect("step");
            }
            let mut park = Summary::new();
            let mut resume = Summary::new();
            for _ in 0..iters {
                let t0 = Instant::now();
                be.preempt(7, seq, mode).expect("preempt");
                park.add(t0.elapsed().as_secs_f64());
                let t1 = Instant::now();
                seq = be.resume(7, &prompt, &produced).expect("resume");
                resume.add(t1.elapsed().as_secs_f64());
            }
            be.finish(seq);
            assert_eq!(be.engine.pool().allocated_pages(), 0, "preempt bench leaked pages");
            let moved = match mode {
                PreemptMode::Restore => {
                    be.engine.metrics.counter("preempt.restore_bytes") / iters as u64
                }
                PreemptMode::Recompute => {
                    be.engine.metrics.counter("preempt.recompute_tokens") / iters as u64
                }
            };
            let unit = match mode {
                PreemptMode::Restore => "bytes",
                PreemptMode::Recompute => "tokens",
            };
            let cycle = park.mean() + resume.mean();
            println!(
                "{:<22} {:>8} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9} {}",
                format!("preempt/{}/p{plen}", mode.name()),
                plen,
                park.mean() * 1e3,
                resume.mean() * 1e3,
                cycle * 1e3,
                moved,
                unit
            );
            let mut row = vec![
                ("name", Json::str(format!("preempt/{}/p{plen}", mode.name()))),
                ("mode", Json::str(mode.name())),
                ("prompt", Json::from(plen)),
                ("history_tokens", Json::from(produced.len())),
                ("iters", Json::from(iters)),
                ("park_mean_secs", Json::from(park.mean())),
                ("resume_mean_secs", Json::from(resume.mean())),
                ("cycle_mean_secs", Json::from(cycle)),
            ];
            match mode {
                PreemptMode::Restore => {
                    row.push(("restore_bytes_per_cycle", Json::from(moved as usize)))
                }
                PreemptMode::Recompute => {
                    row.push(("recompute_tokens_per_cycle", Json::from(moved as usize)))
                }
            }
            rows.push(Json::obj(row));
        }
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_robustness.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_robustness.json");
    println!("\nwrote results/BENCH_robustness.json");
}
