//! Supervised serving benchmark (ISSUE 9): throughput, TTFT and
//! inter-token latency of the supervisor + scored router + replica fleet
//! at 2/4/8 replicas under Poisson and bursty arrivals, with and without
//! one replica crash mid-run.
//!
//!     cargo bench --bench serving              # full run
//!     cargo bench --bench serving -- --test    # CI smoke (2 replicas)
//!
//! Writes `results/BENCH_serving.json` (uploaded by the CI bench-smoke
//! job; `scripts/bench_compare.py` gates the `*_tokens_per_sec` and
//! `ttft_*_secs` keys against `results/baselines/`).  Row naming:
//! `serving/r{N}/{poisson|bursty}[/crash]` — the `/crash` cells kill
//! replica 0 on its 10th tick and include the recovery cost in every
//! percentile.

use std::sync::mpsc::channel;
use std::time::Instant;

use raas::config::{EngineConfig, PolicyKind};
use raas::coordinator::batcher::BatcherConfig;
use raas::coordinator::request::{Outcome, Request, Response};
use raas::coordinator::router::RoutePolicy;
use raas::coordinator::supervisor::{Supervisor, SupervisorConfig};
use raas::runtime::FaultSchedule;
use raas::util::clock::WallClock;
use raas::util::json::Json;
use raas::util::rng::Rng;
use raas::util::stats::Summary;

struct CellStats {
    done: usize,
    failed: usize,
    crashes: u64,
    redispatched: u64,
    tokens: usize,
    wall_secs: f64,
    ttfts: Vec<f64>,
    intertokens: Vec<f64>,
}

/// One serving cell: `n_reqs` requests against `n` supervised replicas
/// under the given arrival process, optionally crashing replica 0 on its
/// 10th tick.
fn serve_cell(n: usize, bursty: bool, crash: bool, n_reqs: u64, max_new: usize) -> CellStats {
    let cfg = EngineConfig { policy: PolicyKind::Raas, budget: 96, seed: 7, ..Default::default() };
    let faults = if crash {
        vec![Some(FaultSchedule::new(7).crash_at_tick(10))]
    } else {
        Vec::new()
    };
    let mut sup = Supervisor::spawn(
        n,
        cfg,
        BatcherConfig { max_batch: 4, ..Default::default() },
        Some(vec![64, 128, 256, 512]),
        RoutePolicy::Scored,
        SupervisorConfig::default(),
        WallClock::shared(),
        faults,
    )
    .expect("spawn supervisor");
    let mut rng = Rng::new(11);
    let (tx, rx) = channel::<Response>();
    let t0 = Instant::now();
    for id in 0..n_reqs {
        if bursty {
            // bursts of 8 back-to-back arrivals separated by a quiet gap
            if id > 0 && id % 8 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(4));
            }
        } else {
            // Poisson arrivals, ~500 req/s offered load
            std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(500.0)));
        }
        let prompt: Vec<u32> = (0..32).map(|i| 1 + ((i + id as usize) % 40) as u32).collect();
        let req = Request::new(id, prompt, max_new, tx.clone()).with_retries(2);
        if let Err(se) = sup.submit(req) {
            let _ = se.req.reply.send(Response::err(se.req.id, se.req.submitted, se.reason));
        }
        sup.poll();
    }
    drop(tx);
    assert!(sup.run_until_idle(2_000_000), "serving bench must drain, not wedge");
    let wall_secs = t0.elapsed().as_secs_f64();
    let (crashes, redispatched) = (sup.crashes, sup.redispatched);
    sup.shutdown();
    let mut s = CellStats {
        done: 0,
        failed: 0,
        crashes,
        redispatched,
        tokens: 0,
        wall_secs,
        ttfts: Vec::new(),
        intertokens: Vec::new(),
    };
    for r in rx.iter() {
        match r.outcome {
            Outcome::Done => {
                s.done += 1;
                s.tokens += r.tokens.len();
                s.ttfts.push(r.ttft_secs);
                if r.tokens.len() > 1 {
                    s.intertokens
                        .push((r.jct_secs - r.ttft_secs).max(0.0) / (r.tokens.len() - 1) as f64);
                }
            }
            Outcome::Failed | Outcome::Shed => s.failed += 1,
        }
    }
    assert_eq!(s.done + s.failed, n_reqs as usize, "serving bench lost requests");
    s
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test" || a == "--quick");
    let replica_counts: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    let max_new = 24usize;
    let mut rows: Vec<Json> = Vec::new();
    println!(
        "{:<26} {:>5} {:>5} {:>7} {:>10} {:>12} {:>9} {:>9} {:>9} {:>9}",
        "benchmark", "done", "fail", "redisp", "req/s", "tok/s", "ttft p50", "ttft p99",
        "itl p50", "itl p99"
    );
    println!("{}", "-".repeat(112));
    for &n in replica_counts {
        let n_reqs = if quick { 3 * n as u64 } else { 6 * n as u64 };
        for bursty in [false, true] {
            let arrival = if bursty { "bursty" } else { "poisson" };
            for crash in [false, true] {
                let name = if crash {
                    format!("serving/r{n}/{arrival}/crash")
                } else {
                    format!("serving/r{n}/{arrival}")
                };
                let s = serve_cell(n, bursty, crash, n_reqs, max_new);
                let mut ttft = Summary::new();
                ttft.extend(s.ttfts.iter().copied());
                let mut itl = Summary::new();
                itl.extend(s.intertokens.iter().copied());
                let rps = s.done as f64 / s.wall_secs;
                let tps = s.tokens as f64 / s.wall_secs;
                println!(
                    "{:<26} {:>5} {:>5} {:>7} {:>10.1} {:>12.0} {:>6.2}ms {:>6.2}ms \
                     {:>6.3}ms {:>6.3}ms",
                    name,
                    s.done,
                    s.failed,
                    s.redispatched,
                    rps,
                    tps,
                    1e3 * ttft.percentile(50.0),
                    1e3 * ttft.percentile(99.0),
                    1e3 * itl.percentile(50.0),
                    1e3 * itl.percentile(99.0)
                );
                if crash {
                    assert_eq!(s.crashes, 1, "{name}: the injected crash must fire");
                }
                rows.push(Json::obj(vec![
                    ("name", Json::str(name)),
                    ("replicas", Json::from(n)),
                    ("arrival", Json::str(arrival)),
                    ("crash", Json::from(if crash { 1usize } else { 0 })),
                    ("requests", Json::from(n_reqs as usize)),
                    ("max_new", Json::from(max_new)),
                    ("done", Json::from(s.done)),
                    ("failed", Json::from(s.failed)),
                    ("crashes", Json::from(s.crashes as usize)),
                    ("redispatched", Json::from(s.redispatched as usize)),
                    ("requests_per_sec", Json::from(rps)),
                    ("goodput_tokens_per_sec", Json::from(tps)),
                    ("ttft_p50_secs", Json::from(ttft.percentile(50.0))),
                    ("ttft_p99_secs", Json::from(ttft.percentile(99.0))),
                    ("intertoken_p50_secs", Json::from(itl.percentile(50.0))),
                    ("intertoken_p99_secs", Json::from(itl.percentile(99.0))),
                ]));
            }
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/BENCH_serving.json", Json::Arr(rows).to_string())
        .expect("write results/BENCH_serving.json");
    println!("\nwrote results/BENCH_serving.json");
}
