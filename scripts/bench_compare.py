#!/usr/bin/env python3
"""Bench regression gate: compare fresh results/BENCH_*.json against the
committed snapshots in results/baselines/, print per-metric deltas as a
markdown table (stdout + $GITHUB_STEP_SUMMARY when set), and fail on >20%
regressions in the gated metrics — decode throughput and TTFT.

Stdlib only (CI runners get no pip step for this).

Baseline file shapes:
  * a raw JSON array of rows (what the benches write) — a real snapshot;
    gated regressions against it FAIL the job.
  * {"provisional": true, "rows": [...]} — a hand-seeded placeholder from
    an environment that could not run the benches; regressions only WARN.
    Replace with a real run's artifact to arm the gate.

Gated metrics (matched per row by key):
  * keys containing "tokens_per_sec"            — higher is better
  * keys containing "ttft" and ending "_secs"   — lower is better
Every other shared numeric metric is reported, never gated (wall-clock
noise on shared runners makes tight gates on tail stats flappy).

Usage:
  python3 scripts/bench_compare.py [--baselines DIR] [--results DIR]
                                   [--threshold PCT]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

THRESHOLD = 20.0  # percent


def is_gated(key: str) -> bool:
    return higher_better(key) or lower_better(key)


def higher_better(key: str) -> bool:
    return "tokens_per_sec" in key


def lower_better(key: str) -> bool:
    return "ttft" in key and key.endswith("_secs")


def load_rows(path: str):
    """Return (rows, provisional) for one bench JSON file."""
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if isinstance(data, dict):
        return data.get("rows", []), bool(data.get("provisional", False))
    return data, False


def index_rows(rows):
    return {r["name"]: r for r in rows if isinstance(r, dict) and "name" in r}


def compare_file(name, base_path, new_path, threshold):
    """Yield (row, metric, base, new, delta_pct, status) tuples."""
    base_rows, provisional = load_rows(base_path)
    new_rows, _ = load_rows(new_path)
    base_idx, new_idx = index_rows(base_rows), index_rows(new_rows)
    out = []
    for row_name in sorted(set(base_idx) & set(new_idx)):
        b, n = base_idx[row_name], new_idx[row_name]
        for key in sorted(set(b) & set(n)):
            if key == "name":
                continue
            bv, nv = b[key], n[key]
            if not isinstance(bv, (int, float)) or not isinstance(nv, (int, float)):
                continue
            if not is_gated(key):
                continue
            delta = 0.0 if bv == 0 else (nv - bv) / abs(bv) * 100.0
            worse = -delta if higher_better(key) else delta
            if worse > threshold:
                status = "warn (provisional baseline)" if provisional else "REGRESSION"
            else:
                status = "ok"
            out.append((row_name, key, bv, nv, delta, status))
    return out, provisional


def fmt(v):
    return f"{v:.6g}" if isinstance(v, float) else str(v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baselines", default="results/baselines")
    ap.add_argument("--results", default="results")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args()

    lines = ["## Bench regression gate", ""]
    lines.append(f"Gate: >{args.threshold:.0f}% regression on decode throughput / TTFT "
                 "metrics fails the job (warn-only against provisional baselines).")
    lines.append("")
    failures = 0
    compared = 0
    bench_files = sorted(
        f for f in os.listdir(args.results)
        if f.startswith("BENCH_") and f.endswith(".json")
        and os.path.isfile(os.path.join(args.results, f))
    ) if os.path.isdir(args.results) else []
    if not bench_files:
        print(f"error: no BENCH_*.json under {args.results}", file=sys.stderr)
        return 2

    for fname in bench_files:
        base_path = os.path.join(args.baselines, fname)
        new_path = os.path.join(args.results, fname)
        lines.append(f"### {fname}")
        lines.append("")
        if not os.path.exists(base_path):
            lines.append("_no baseline committed — new bench, nothing to gate_")
            lines.append("")
            continue
        rows, provisional = compare_file(fname, base_path, new_path, args.threshold)
        if provisional:
            lines.append("_baseline is provisional: deltas reported, gate warns only_")
            lines.append("")
        if not rows:
            lines.append("_no shared gated metrics_")
            lines.append("")
            continue
        lines.append("| row | metric | baseline | current | delta | status |")
        lines.append("|---|---|---:|---:|---:|---|")
        for row_name, key, bv, nv, delta, status in rows:
            compared += 1
            if status == "REGRESSION":
                failures += 1
            lines.append(f"| {row_name} | {key} | {fmt(bv)} | {fmt(nv)} "
                         f"| {delta:+.1f}% | {status} |")
        lines.append("")

    verdict = (f"**{failures} gated regression(s)** across {compared} compared metric(s)."
               if failures else
               f"No gated regressions across {compared} compared metric(s).")
    lines.append(verdict)
    report = "\n".join(lines)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(report + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
